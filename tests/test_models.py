"""Per-arch smoke tests + model-level invariants.

Every assigned architecture instantiates its REDUCED config and runs one
forward + one train step on CPU, asserting shapes and finiteness. Family
invariants: prefill+decode equals full forward; losses fall on the
synthetic Markov data.
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro import configs
from repro.core.config import GemminiConfig
from repro.core.generator import elaborate
from repro.models import transformer as tf
from repro.optim import adamw

ENGINE = elaborate(GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                                 output_dtype="bf16"), "xla")


def _toks(cfg, b, t, rng):
    shape = (b, t, cfg.n_codebooks) if cfg.n_codebooks > 1 else (b, t)
    return jnp.asarray(rng.integers(0, cfg.vocab, shape), jnp.int32)


@pytest.mark.parametrize("arch", configs.names())
def test_smoke_forward(arch, rng):
    cfg = configs.get_smoke(arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = _toks(cfg, 2, 16, rng)
    logits = tf.forward(ENGINE, params, cfg, toks)
    t_out = 16 + cfg.n_meta_tokens
    if cfg.n_codebooks > 1:
        assert logits.shape == (2, t_out, cfg.n_codebooks, cfg.vocab)
    else:
        assert logits.shape == (2, t_out, cfg.vocab)
    assert bool(jnp.all(jnp.isfinite(logits)))


@pytest.mark.parametrize("arch", configs.names())
def test_smoke_train_step(arch, rng):
    cfg = configs.get_smoke(arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = _toks(cfg, 2, 16, rng)
    loss, grads = jax.value_and_grad(
        lambda p: tf.loss_fn(ENGINE, p, cfg, toks, toks))(params)
    assert bool(jnp.isfinite(loss))
    gnorm = adamw.global_norm(grads)
    assert bool(jnp.isfinite(gnorm)) and float(gnorm) > 0


@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-1.3b", "hymba-1.5b",
                                  "granite-moe-3b-a800m"])
def test_prefill_decode_matches_forward(arch, rng):
    """logits(prefill(prompt)) + decode steps == forward(full sequence)."""
    import dataclasses
    cfg = configs.get_smoke(arch)
    if cfg.n_meta_tokens:
        cfg = dataclasses.replace(cfg, n_meta_tokens=0)
    if cfg.family == "moe":
        # forward uses capacity-bounded dispatch, serving is dropless; a
        # huge capacity factor makes the training path dropless too so the
        # two are comparable.
        cfg = dataclasses.replace(cfg, capacity_factor=64.0)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    b, t_prompt, t_extra = 2, 12, 4
    toks = _toks(cfg, b, t_prompt + t_extra, rng)

    full = tf.forward(ENGINE, params, cfg, toks)

    state = tf.init_decode_state(cfg, b, t_prompt + t_extra,
                                 dtype=cfg.dtype)
    state = state._replace(pos=jnp.zeros((), jnp.int32))
    logits_p, state = tf.prefill_into_cache(ENGINE, params, cfg,
                                            toks[:, :t_prompt], state)
    np.testing.assert_allclose(
        np.asarray(logits_p, np.float32),
        np.asarray(full[:, :t_prompt], np.float32), rtol=2e-2, atol=2e-2)

    outs = []
    for i in range(t_extra):
        step_tok = toks[:, t_prompt + i][:, None]
        logits_d, state = tf.decode_step(ENGINE, params, cfg, step_tok,
                                         state)
        outs.append(logits_d[:, 0])
    dec = jnp.stack(outs, axis=1)
    np.testing.assert_allclose(
        np.asarray(dec, np.float32),
        np.asarray(full[:, t_prompt:], np.float32), rtol=2e-2, atol=2e-2)


def test_local_global_window_pattern():
    cfg = configs.get("gemma3-4b")
    win = tf.layer_windows(cfg, 4096)
    # 5 local : 1 global (every 6th layer is global => window 0)
    assert win[5] == 0 and win[11] == 0
    assert all(w == cfg.local_window for i, w in enumerate(win)
               if (i + 1) % 6 != 0)


@pytest.mark.slow
def test_loss_decreases_on_markov_data(rng):
    """End-to-end sanity: a few optimizer steps reduce the loss."""
    from repro.data import SyntheticLM, SyntheticLMConfig
    cfg = configs.get_smoke("gemma3-1b")
    dcfg = SyntheticLMConfig(vocab=cfg.vocab, seq=64, global_batch=8,
                             branching=2)
    gen = SyntheticLM(dcfg)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    opt = adamw.adamw_init(params)
    ocfg = adamw.AdamWConfig(lr=3e-3)

    @jax.jit
    def step(params, opt, toks):
        loss, g = jax.value_and_grad(
            lambda p: tf.loss_fn(ENGINE, p, cfg, toks, toks))(params)
        params, opt, _ = adamw.adamw_update(ocfg, params, g, opt)
        return params, opt, loss

    losses = []
    for i in range(12):
        batch = gen.host_batch(i, range(8))
        params, opt, loss = step(params, opt,
                                 jnp.asarray(batch["tokens"]))
        losses.append(float(loss))
    assert losses[-1] < losses[0] - 0.1, losses


def test_param_count_analytic_vs_actual():
    """ModelConfig.param_count() (used for MODEL_FLOPS) matches the real
    parameter tree within ~2% (norm/scalars excluded from the analytic)."""
    for arch in ["gemma3-1b", "mamba2-1.3b", "granite-moe-3b-a800m"]:
        cfg = configs.get_smoke(arch)
        params = tf.init_params(jax.random.PRNGKey(0), cfg)
        actual = sum(x.size for x in jax.tree.leaves(params))
        analytic = cfg.param_count()
        assert abs(actual - analytic) / actual < 0.05, (arch, actual,
                                                        analytic)


def test_full_configs_match_assignment():
    """Spot-check the full-size configs against the assignment sheet."""
    c = configs.get("llava-next-34b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.d_ff,
            c.vocab) == (60, 7168, 56, 8, 20480, 64000)
    c = configs.get("gemma2-2b")
    assert (c.n_layers, c.d_model, c.n_heads, c.n_kv_heads, c.vocab) == \
        (26, 2304, 8, 4, 256000)
    assert c.attn_softcap and c.local_window
    c = configs.get("qwen1.5-4b")
    assert c.qkv_bias and c.vocab == 151936 and c.n_layers == 40
    c = configs.get("granite-moe-3b-a800m")
    assert c.n_experts == 40 and c.top_k == 8 and c.moe_d_ff == 512
    c = configs.get("llama4-scout-17b-a16e")
    assert c.n_experts == 16 and c.top_k == 1
    c = configs.get("musicgen-medium")
    assert c.n_codebooks == 4 and c.vocab == 2048
    c = configs.get("hymba-1.5b")
    assert c.family == "hybrid" and c.d_state == 16
    c = configs.get("mamba2-1.3b")
    assert c.family == "ssm" and c.d_state == 128 and not c.has_attn
