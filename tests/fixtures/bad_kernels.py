# Known-bad kernel source, AST-scanned by the lint golden tests
# (tests/test_lint.py). NEVER imported or executed — each function below
# exists to trip exactly one source-level diagnostic, locking the rule's
# behavior. Do not "fix" these.

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels.contracts import kernel_contract


def _body(a_ref, o_ref):
    # GL502: dot_general with no preferred_element_type — bf16 inputs
    # would accumulate at input precision.
    o_ref[...] = jax.lax.dot_general(
        a_ref[...], a_ref[...], (((1,), (0,)), ((), ())))


def unannotated_launch(a):
    # GL501: pallas_call in a function with no @kernel_contract.
    # GL503: no compiler_params -> Mosaic serializes every axis.
    return pl.pallas_call(
        _body,
        grid=(4,),
        in_specs=[pl.BlockSpec((128, 128), lambda i: (i, 0))],
        out_specs=pl.BlockSpec((128, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((512, 128), jnp.float32),
    )(a)


@kernel_contract("no_such_contract")
def unregistered_launch(a):
    # GL501 (unregistered): the annotation names no registered builder.
    # GL504: input_output_aliases undeclared by any contract.
    # GL505: rank-1 scalar BlockSpec without memory_space.
    return pl.pallas_call(
        _body,
        grid=(4,),
        in_specs=[pl.BlockSpec((1,), lambda i: (0,))],
        out_specs=pl.BlockSpec((128, 128), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((512, 128), jnp.float32),
        input_output_aliases={0: 0},
        compiler_params=pltpu.TPUCompilerParams(
            dimension_semantics=("arbitrary",)),
    )(a)


def resurrected_shim(op):
    # GL506: the removed ops.*(backend=...) deprecation machinery.
    return _deprecated_shim(op)  # noqa: F821 — deliberately undefined
