"""MoE dispatch invariants: capacity, padding masks, routing math."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import GemminiConfig
from repro.core.generator import elaborate
from repro.models import moe

ENGINE = elaborate(GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                                 output_dtype="bf16"), "xla")


def _setup(rng, d=16, d_ff=8, n_experts=4, ep=1, n_shared=0):
    key = jax.random.PRNGKey(int(rng.integers(0, 1 << 30)))
    p = moe.moe_init(key, d, d_ff, n_experts, ep=ep, n_shared=n_shared,
                     dtype=jnp.float32)
    return p


def _dense_reference(p, x, n_experts, top_k, router_weights_before=False):
    """O(tokens * E) dense-compute reference (no capacity drops)."""
    nt, d = x.shape
    logits = x @ p["router"][:, :]
    pad_mask = jnp.arange(p["wi"].shape[0]) >= n_experts
    logits = jnp.where(pad_mask[None], -jnp.inf, logits)
    gw, gi = jax.lax.top_k(logits, top_k)
    w = jax.nn.sigmoid(gw) if top_k == 1 else jax.nn.softmax(gw, axis=-1)
    out = jnp.zeros_like(x)
    for e in range(n_experts):
        xin = x
        h = jax.nn.silu(xin @ p["wg"][e]) * (xin @ p["wi"][e])
        ye = h @ p["wo"][e]
        for kk in range(top_k):
            sel = (gi[:, kk] == e).astype(x.dtype)
            if router_weights_before:
                # weight applied to input: expert(w*x) for linear-ish check
                h2 = jax.nn.silu((x * w[:, kk:kk + 1]) @ p["wg"][e]) * \
                    ((x * w[:, kk:kk + 1]) @ p["wi"][e])
                ye2 = h2 @ p["wo"][e]
                out = out + sel[:, None] * ye2
            else:
                out = out + (sel * w[:, kk])[:, None] * ye
    return out


@pytest.mark.parametrize("top_k", [1, 2])
def test_moe_matches_dense_reference(rng, top_k):
    """With ample capacity, the scatter/gather dispatch equals the dense
    per-expert computation."""
    p = _setup(rng)
    x = jnp.asarray(rng.standard_normal((2, 8, 16)), jnp.float32)
    y = moe.moe_apply(ENGINE, p, x, n_experts=4, top_k=top_k,
                      capacity_factor=8.0)
    yr = _dense_reference(p, x.reshape(-1, 16), 4, top_k).reshape(x.shape)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-5)


def test_padded_experts_never_selected(rng):
    """granite: 40 experts padded to 48 on a 16-way EP axis; padded slots
    must receive zero tokens."""
    p = _setup(rng, n_experts=5, ep=4)           # padded to 8
    assert p["wi"].shape[0] == 8
    x = jnp.asarray(rng.standard_normal((4, 16, 16)), jnp.float32)
    nt = 4 * 16
    xf = x.reshape(nt, 16)
    logits = xf @ p["router"]
    pad_mask = jnp.arange(8) >= 5
    logits = jnp.where(pad_mask[None], -jnp.inf, logits)
    _, gi = jax.lax.top_k(logits, 2)
    assert int(jnp.max(gi)) < 5
    # and the full apply is finite
    y = moe.moe_apply(ENGINE, p, x, n_experts=5, top_k=2)
    assert bool(jnp.all(jnp.isfinite(y)))


def test_capacity_drops_are_bounded(rng):
    """With capacity_factor=1.0 and a skewed router, outputs stay finite and
    dropped tokens contribute zero (GShard semantics)."""
    p = _setup(rng)
    # skew: make expert 0 the argmax for every token
    p = dict(p)
    p["router"] = p["router"].at[:, 0].set(10.0)
    x = jnp.asarray(rng.standard_normal((2, 32, 16)), jnp.float32)
    y = moe.moe_apply(ENGINE, p, x, n_experts=4, top_k=1,
                      capacity_factor=0.25)
    assert bool(jnp.all(jnp.isfinite(y)))
    # most tokens beyond the capacity must be exactly zero (dropped)
    flat = np.asarray(y).reshape(-1, 16)
    n_zero = (np.abs(flat).sum(-1) == 0).sum()
    assert n_zero > 0


def test_shared_expert_added(rng):
    p = _setup(rng, n_shared=1)
    x = jnp.asarray(rng.standard_normal((1, 4, 16)), jnp.float32)
    y_with = moe.moe_apply(ENGINE, p, x, n_experts=4, top_k=1)
    p2 = {k: v for k, v in p.items() if k != "shared"}
    y_without = moe.moe_apply(ENGINE, p2, x, n_experts=4, top_k=1)
    assert float(jnp.max(jnp.abs(y_with - y_without))) > 1e-6


def test_load_balance_loss_uniform_is_one(rng):
    """Perfectly uniform routing gives aux loss == 1 (E * sum(1/E * 1/E))."""
    n, e = 1024, 8
    logits = jnp.zeros((n, e))
    gate_idx = jnp.asarray(rng.integers(0, e, (n, 1)))
    loss = moe.aux_load_balance_loss(logits, gate_idx, e, 1)
    assert abs(float(loss) - 1.0) < 0.15
