"""Implicit-im2col conv kernel: bit-exact vs the explicit-im2col oracle,
and the oracle itself vs lax.conv_general_dilated."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import Activation, GemminiConfig
from repro.kernels import conv as ck
from repro.core.context import ExecutionContext
from repro.kernels import ref

CASES = [
    # n, h, w, ci, co, kh, kw, stride, pad
    (2, 12, 12, 8, 16, 3, 3, 1, 1),
    (1, 16, 16, 4, 20, 1, 1, 1, 0),    # pointwise (resnet 1x1)
    (1, 15, 15, 8, 8, 3, 3, 2, 1),     # strided
    (2, 14, 10, 16, 12, 5, 3, 1, 2),   # rectangular kernel
    (1, 8, 8, 3, 32, 7, 7, 2, 3),      # resnet stem-like
]


@pytest.mark.parametrize("case", CASES)
def test_implicit_conv_bitexact(rng, case):
    n, h, w, ci, co, kh, kw, stride, pad = case
    cfg = GemminiConfig()
    x = jnp.asarray(rng.integers(-64, 64, (n, h, w, ci)), jnp.int8)
    wt = jnp.asarray(rng.integers(-32, 32, (kh, kw, ci, co)), jnp.int8)
    b = jnp.asarray(rng.integers(-500, 500, (co,)), jnp.int32)
    y = ck.conv2d_implicit(x, wt, b, cfg=cfg, stride=stride, padding=pad,
                           shift=7, activation=Activation.RELU, co_tile=8,
                           interpret=True)
    yr = ref.conv2d_ref(x, wt, b, stride=stride, padding=pad,
                        acc_dtype=jnp.int32, out_dtype=jnp.int8, shift=7,
                        activation=Activation.RELU)
    assert bool(jnp.all(y == yr)), np.abs(np.asarray(y, np.int32) -
                                          np.asarray(yr, np.int32)).max()


def test_oracle_vs_lax_conv(rng):
    """The explicit-im2col oracle reproduces XLA's convolution."""
    n, h, w, ci, co = 2, 10, 10, 4, 6
    x = jnp.asarray(rng.standard_normal((n, h, w, ci)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, ci, co)), jnp.float32)
    y = ref.conv2d_ref(x, wt, None, stride=1, padding=1,
                       acc_dtype=jnp.float32, out_dtype=jnp.float32)
    y_lax = jax.lax.conv_general_dilated(
        x, wt, (1, 1), ((1, 1), (1, 1)),
        dimension_numbers=("NHWC", "HWIO", "NHWC"))
    np.testing.assert_allclose(np.asarray(y), np.asarray(y_lax),
                               rtol=1e-4, atol=1e-4)


def test_ops_conv_host_im2col_matches_fused(rng):
    """The paper's shipped path (host im2col + engine GEMM) and the fused
    kernel (paper section 7) agree bit-for-bit."""
    cfg = GemminiConfig()
    x = jnp.asarray(rng.integers(-64, 64, (1, 10, 10, 8)), jnp.int8)
    wt = jnp.asarray(rng.integers(-32, 32, (3, 3, 8, 16)), jnp.int8)
    ctx = ExecutionContext(cfg=cfg, backend="interpret")
    y_host = ctx.conv2d(x, wt, None, stride=1, padding=1, shift=6,
                        activation=Activation.RELU, fused=False)
    y_fused = ctx.conv2d(x, wt, None, stride=1, padding=1, shift=6,
                         activation=Activation.RELU, fused=True)
    assert bool(jnp.all(y_host == y_fused))


def test_conv_bias_operand_hoisted(rng):
    """Bias-free convs stream no dummy bias block through the tap stream:
    the pallas_call takes 2 operands without a bias, 3 with one."""
    cfg = GemminiConfig()
    x = jnp.zeros((1, 10, 10, 8), jnp.int8)
    wt = jnp.zeros((3, 3, 8, 16), jnp.int8)
    b = jnp.zeros((16,), jnp.int32)

    def n_pallas_operands(fn, *args):
        jaxpr = jax.make_jaxpr(fn)(*args)
        eqn = next(e for e in jaxpr.eqns
                   if "pallas_call" in str(e.primitive))
        return len(eqn.invars)

    assert n_pallas_operands(
        lambda x, wt: ck.conv2d_implicit(x, wt, None, cfg=cfg, co_tile=8,
                                         interpret=True), x, wt) == 2
    assert n_pallas_operands(
        lambda x, wt, b: ck.conv2d_implicit(x, wt, b, cfg=cfg, co_tile=8,
                                            interpret=True), x, wt, b) == 3


def test_ops_conv_fused_xla_routes_to_fused_equivalent_ref(rng):
    """fused=True on the xla backend routes to conv2d_ref (documented as
    the fused-equivalent reference), bit-identical to the fused kernel."""
    cfg = GemminiConfig()
    x = jnp.asarray(rng.integers(-64, 64, (1, 10, 10, 8)), jnp.int8)
    wt = jnp.asarray(rng.integers(-32, 32, (3, 3, 8, 16)), jnp.int8)
    b = jnp.asarray(rng.integers(-500, 500, (16,)), jnp.int32)
    y_xla = ExecutionContext(cfg=cfg, backend="xla").conv2d(
        x, wt, b, stride=1, padding=1, shift=6,
        activation=Activation.RELU, fused=True)
    y_fused = ExecutionContext(cfg=cfg, backend="interpret").conv2d(
        x, wt, b, stride=1, padding=1, shift=6,
        activation=Activation.RELU, fused=True)
    assert bool(jnp.all(y_xla == y_fused))


def test_float_conv(rng):
    cfg = GemminiConfig(input_dtype="fp32", acc_dtype="fp32",
                        output_dtype="fp32")
    x = jnp.asarray(rng.standard_normal((1, 9, 9, 4)), jnp.float32)
    wt = jnp.asarray(rng.standard_normal((3, 3, 4, 8)), jnp.float32)
    y = ck.conv2d_implicit(x, wt, None, cfg=cfg, stride=1, padding=1,
                           co_tile=8, interpret=True)
    yr = ref.conv2d_ref(x, wt, None, stride=1, padding=1,
                        acc_dtype=jnp.float32, out_dtype=jnp.float32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=1e-4, atol=1e-4)
