"""Every perf flag must be numerically equivalent to the baseline path.

The §Perf optimizations change schedules/shardings, never math: these
tests pin that contract so hillclimbing can't silently change results.
"""

import numpy as np
import jax
import jax.numpy as jnp
import pytest

from repro import configs
from repro.core import flags
from repro.core.config import GemminiConfig
from repro.core.generator import elaborate
from repro.kernels import ref
from repro.models import attention as mattn
from repro.models import transformer as tf

ENGINE = elaborate(GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                                 output_dtype="bf16"), "xla")


@pytest.fixture(autouse=True)
def _reset_flags():
    flags.reset()
    yield
    flags.reset()


def test_onehot_cache_update_equals_dus(rng):
    k = jnp.asarray(rng.standard_normal((2, 16, 4, 8)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 16, 4, 8)), jnp.float32)
    kn = jnp.asarray(rng.standard_normal((2, 1, 4, 8)), jnp.float32)
    vn = jnp.asarray(rng.standard_normal((2, 1, 4, 8)), jnp.float32)
    pos = jnp.int32(5)
    c0 = mattn.update_cache(mattn.KVCache(k, v), kn, vn, pos)
    flags.set_flag("onehot_cache_update", True)
    c1 = mattn.update_cache(mattn.KVCache(k, v), kn, vn, pos)
    np.testing.assert_array_equal(np.asarray(c0.k), np.asarray(c1.k))
    np.testing.assert_array_equal(np.asarray(c0.v), np.asarray(c1.v))


def test_gqa_grouped_decode_equals_baseline(rng):
    q = jnp.asarray(rng.standard_normal((2, 1, 8, 32)), jnp.float32)
    k = jnp.asarray(rng.standard_normal((2, 64, 2, 32)), jnp.float32)
    v = jnp.asarray(rng.standard_normal((2, 64, 2, 32)), jnp.float32)
    pos = jnp.int32(40)
    y0 = mattn.decode_attention(q, mattn.KVCache(k, v), pos, window=16)
    flags.set_flag("gqa_grouped_decode", True)
    y1 = mattn.decode_attention(q, mattn.KVCache(k, v), pos, window=16)
    np.testing.assert_allclose(np.asarray(y0), np.asarray(y1),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("flag", ["cache_as_carry", "decode_unroll"])
@pytest.mark.parametrize("arch", ["gemma2-2b", "mamba2-1.3b"])
def test_decode_restructure_equals_baseline(rng, flag, arch):
    cfg = configs.get_smoke(arch)
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 1)), jnp.int32)
    state = tf.init_decode_state(cfg, 2, 32, dtype=cfg.dtype)
    state = state._replace(pos=jnp.asarray(10, jnp.int32))
    l0, s0 = tf.decode_step(ENGINE, params, cfg, toks, state)
    flags.set_flag(flag, True)
    l1, s1 = tf.decode_step(ENGINE, params, cfg, toks, state)
    np.testing.assert_allclose(
        np.asarray(l0, np.float32), np.asarray(l1, np.float32),
        rtol=5e-2, atol=5e-2)      # bf16 reassociation tolerance
    for a, b in zip(jax.tree.leaves(s0), jax.tree.leaves(s1)):
        np.testing.assert_allclose(
            np.asarray(a, np.float32), np.asarray(b, np.float32),
            rtol=5e-2, atol=5e-2)


def test_moe_grouped_dispatch_equals_baseline_on_mesh(run_subprocess):
    code = """
import numpy as np, jax, jax.numpy as jnp
from repro.core import flags
from repro.launch.mesh import activate_mesh, make_mesh
from repro.core.config import GemminiConfig
from repro.core.generator import elaborate
from repro.models import moe

ENGINE = elaborate(GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                                 output_dtype="bf16"), "xla")
mesh = make_mesh((2, 4), ("data", "model"))
rng = np.random.default_rng(0)
p = moe.moe_init(jax.random.PRNGKey(1), 16, 8, 4, ep=4, dtype=jnp.float32)
x = jnp.asarray(rng.standard_normal((4, 16, 16)), jnp.float32)
with activate_mesh(mesh):
    y0 = jax.jit(lambda p, x: moe.moe_apply(
        ENGINE, p, x, n_experts=4, top_k=2, capacity_factor=64.0))(p, x)
    flags.set_flag("moe_grouped_dispatch", 1)
    y1 = jax.jit(lambda p, x: moe.moe_apply(
        ENGINE, p, x, n_experts=4, top_k=2, capacity_factor=64.0))(p, x)
    flags.reset()
assert float(jnp.max(jnp.abs(y1 - y0))) < 1e-4
print("MOE GROUPED OK")
"""
    assert "MOE GROUPED OK" in run_subprocess(code, n_devices=8)


@pytest.mark.parametrize("policy", ["dots", "none"])
def test_remat_policy_same_loss(rng, policy):
    cfg = configs.get_smoke("gemma3-1b")
    params = tf.init_params(jax.random.PRNGKey(0), cfg)
    toks = jnp.asarray(rng.integers(0, cfg.vocab, (2, 32)), jnp.int32)
    l0 = tf.loss_fn(ENGINE, params, cfg, toks, toks, remat=True)
    flags.set_flag("remat_policy", policy)
    l1 = tf.loss_fn(ENGINE, params, cfg, toks, toks, remat=True)
    np.testing.assert_allclose(float(l0), float(l1), rtol=1e-4)
