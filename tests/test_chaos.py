"""Chaos suite: deterministic fault injection vs. the self-healing engine.

The invariants under test (ISSUE: self-healing serving):

* **Exactness under degradation.** Under a seeded FaultPlan mixing NaN
  poison, transient failures, arena pressure, and stragglers, every
  request that completes produces tokens bit-identical to the fault-free
  run -- the XLA-twin fallback and retry paths are exact, not
  approximate.
* **No silent loss.** Every submitted request reaches a terminal status
  (finished or shed); fallbacks/retries/sheds all land in telemetry.
* **Off by default.** With no plan, the engine byte-for-byte matches the
  pre-robustness behavior (donating jits, zero counters).
"""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import flags
from repro.core.config import GemminiConfig
from repro.core.context import ExecutionContext
from repro.models import transformer as tf
from repro.runtime import faults
from repro.runtime.faults import (FaultInjector, FaultPlan, FaultSpec,
                                  TransientOpError)
from repro.serving import ServingEngine

_TINY = tf.ModelConfig(name="tiny-serve", family="dense", n_layers=2,
                       d_model=32, vocab=64, n_heads=2, n_kv_heads=1,
                       head_dim=16, d_ff=64, dtype=jnp.float32)

# The adversarial plan the flagship test replays: NaN-poisoned decodes,
# a failed prefill dispatch, straggler-delayed steps, and three steps of
# arena pressure -- all from one seed.
MIXED_PLAN = ("seed=3;nan@decode:p=1,max=2;transient@prefill:max=1;"
              "straggler@step:delay=0.001,start=6,max=2;"
              "arena:pages=2,start=3,max=3")


def _run(faults_spec=None, *, lens=(5, 11, 19), gen=6, backend="interpret",
         **kw):
    rng = np.random.default_rng(0)
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=8, temperature=0.0, seed=0, backend=backend,
                        prefill_chunk=8, faults=faults_spec, **kw)
    for n in lens:
        eng.submit(rng.integers(0, 64, (n,), dtype=np.int32), gen)
    return eng, eng.run()


def _tokens(report):
    return [np.asarray(r["tokens"]).ravel() for r in report["requests"]]


# ---------------------------------------------------------------------------
# plan grammar + determinism
# ---------------------------------------------------------------------------
def test_plan_parse_grammar():
    plan = FaultPlan.parse(
        "seed=7;nan@decode:p=0.25,max=2;transient@prefill:max=1;"
        "arena:pages=2;straggler:delay=0.5;ckpt_io")
    assert plan.seed == 7
    kinds = [s.kind for s in plan.specs]
    assert kinds == ["nan", "transient", "arena", "straggler", "ckpt_io"]
    nan = plan.specs[0]
    assert nan.site == "decode" and nan.p == 0.25 and nan.max_hits == 2
    # bare kinds land on their default sites
    assert plan.specs[2].site == "arena" and plan.specs[2].pages == 2
    assert plan.specs[3].site == "step" and plan.specs[3].delay_s == 0.5
    assert plan.specs[4].site == "checkpoint"
    # offload_io: bare kind defaults to the spill site; @restore targets
    # the restore DMA (docs/serving.md#kv-lifecycle)
    off = FaultPlan.parse("offload_io;offload_io@restore:max=3").specs
    assert off[0].kind == "offload_io" and off[0].site == "spill"
    assert off[1].site == "restore" and off[1].max_hits == 3
    assert FaultPlan.parse("") == FaultPlan()
    with pytest.raises(ValueError):
        FaultPlan.parse("meteor@decode")
    with pytest.raises(ValueError):
        FaultPlan.parse("nan@decode:frequency=2")
    with pytest.raises(ValueError):
        FaultSpec(kind="nan", p=1.5)


def test_plan_from_env(monkeypatch):
    monkeypatch.delenv(faults.ENV_VAR, raising=False)
    assert FaultPlan.from_env() is None
    assert faults.as_injector(None) is None
    monkeypatch.setenv(faults.ENV_VAR, "seed=9;nan@decode")
    plan = FaultPlan.from_env()
    assert plan is not None and plan.seed == 9
    inj = faults.as_injector(None)
    assert inj is not None and inj.plan == plan
    monkeypatch.setenv(faults.ENV_VAR, "  ")
    assert FaultPlan.from_env() is None


def test_injector_deterministic_firing_sequence():
    """Two injectors bound to equal plans fire on identical visits; the
    sequence depends only on the plan and the visit order (the
    reproduce-from-a-seed contract)."""
    plan = FaultPlan.parse("seed=11;nan@decode:p=0.4;transient:p=0.3,max=5")
    a, b = FaultInjector(plan), FaultInjector(plan)
    seq_a, seq_b = [], []
    for inj, seq in ((a, seq_a), (b, seq_b)):
        for _ in range(50):
            spec = inj.fires("decode", ("nan", "inf", "transient"))
            seq.append(None if spec is None else spec.kind)
    assert seq_a == seq_b
    assert any(k == "nan" for k in seq_a)
    # a different seed draws a different sequence
    c = FaultInjector(FaultPlan.parse("seed=12;nan@decode:p=0.4;"
                                      "transient:p=0.3,max=5"))
    seq_c = [c.fires("decode", ("nan", "inf", "transient")) is not None
             for _ in range(50)]
    assert seq_c != [k is not None for k in seq_a]


def test_injector_windows_and_caps():
    inj = FaultInjector(FaultPlan.parse("nan@decode:start=2,stop=4;"
                                        "transient@prefill:max=1"))
    fired = [inj.fires("decode") is not None for _ in range(6)]
    assert fired == [False, False, True, True, False, False]
    with pytest.raises(TransientOpError):
        inj.check_transient("prefill")
    inj.check_transient("prefill")          # max_hits=1: never again
    assert inj.total_injected == 3
    assert inj.report() == {"nan@decode": 2, "transient@prefill": 1}


def test_injector_poison_and_straggle():
    inj = FaultInjector(FaultPlan.parse("inf@decode:max=1;"
                                        "straggler:delay=0.25,max=1"))
    x = jnp.ones((2, 3))
    out = inj.poison("decode", x)
    assert np.all(np.isposinf(np.asarray(out)))
    assert inj.poison("decode", x) is x      # cap reached: pass-through
    assert inj.poison("decode", None) is None
    slept = []
    inj.sleep = slept.append                 # injectable: no real sleep
    assert inj.straggle() == 0.25 and slept == [0.25]
    assert inj.straggle() == 0.0 and slept == [0.25]


# ---------------------------------------------------------------------------
# off by default
# ---------------------------------------------------------------------------
def test_faults_off_is_inert(monkeypatch):
    monkeypatch.delenv(faults.ENV_VAR, raising=False)
    eng, rep = _run(None, lens=(5, 9), gen=3)
    assert eng.faults is None and not eng.nan_guard
    s = rep["summary"]
    assert s["retries"] == 0 and s["fallbacks"] == 0
    assert s["injected_faults"] == 0 and s["shed"] == 0
    assert rep["quarantined"] == [] and "faults" not in rep
    # the fault-free engine keeps the donating (PR-5) jit variant
    from repro.serving.engine import _JIT_CACHE
    assert (eng.engine, _TINY, eng.page_size, True) in _JIT_CACHE


# ---------------------------------------------------------------------------
# eager ExecutionContext op boundary
# ---------------------------------------------------------------------------
def test_eager_ctx_op_poison_and_transient():
    cfg = GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                        output_dtype="bf16")
    ctx = ExecutionContext(cfg=cfg, backend="xla")
    a = jnp.ones((4, 8), jnp.float32)
    b = jnp.ones((8, 8), jnp.float32)
    clean = np.asarray(ctx.matmul(a, b))
    inj = faults.install("nan@op:matmul:max=1;transient@op:matmul:start=1,max=1")
    try:
        out = np.asarray(ctx.matmul(a, b))
        assert np.all(np.isnan(out))
        with pytest.raises(TransientOpError):
            ctx.matmul(a, b)
        # plan exhausted: dispatch is clean again
        np.testing.assert_array_equal(np.asarray(ctx.matmul(a, b)), clean)
        assert inj.report() == {"nan@op:matmul": 1,
                                "transient@op:matmul": 1}
    finally:
        faults.deactivate()
    assert faults.active() is None


def test_traced_ctx_ops_never_fault():
    """Injection is host-level only: under a jit trace the op hook is a
    pass-through, so compiled artifacts stay byte-identical to the
    fault-free run (a trace-time fault would be baked in forever)."""
    cfg = GemminiConfig(input_dtype="bf16")
    ctx = ExecutionContext(cfg=cfg, backend="xla")
    a = jnp.ones((4, 8), jnp.float32)
    b = jnp.ones((8, 8), jnp.float32)
    clean = np.asarray(ctx.matmul(a, b))
    faults.install("nan@op:matmul;transient@op:matmul")
    try:
        out = jax.jit(lambda x, y: ctx.matmul(x, y))(a, b)
        np.testing.assert_array_equal(np.asarray(out), clean)
    finally:
        faults.deactivate()


# ---------------------------------------------------------------------------
# engine hardening: guard, retry, fallback -- all exact
# ---------------------------------------------------------------------------
@pytest.fixture(scope="module")
def reference():
    _, rep = _run(None)
    return _tokens(rep)


@pytest.mark.slow
def test_nan_guard_fallback_exact_tokens(reference):
    eng, rep = _run("seed=1;nan@decode:max=1")
    assert rep["summary"]["fallbacks"] == 1
    assert rep["faults"] == {"nan@decode": 1}
    for a, b in zip(reference, _tokens(rep)):
        np.testing.assert_array_equal(a, b)


def test_inf_guard_on_prefill_exact_tokens(reference):
    eng, rep = _run("seed=1;inf@prefill:max=1")
    assert rep["summary"]["fallbacks"] == 1
    for a, b in zip(reference, _tokens(rep)):
        np.testing.assert_array_equal(a, b)


def test_transient_retry_exact_tokens(reference):
    eng, rep = _run("seed=1;transient@decode:max=2")
    assert rep["summary"]["retries"] == 2
    assert rep["summary"]["fallbacks"] == 0
    for a, b in zip(reference, _tokens(rep)):
        np.testing.assert_array_equal(a, b)


def test_retry_exhaustion_raises():
    rng = np.random.default_rng(0)
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=8, temperature=0.0, seed=0,
                        backend="interpret", prefill_chunk=8,
                        faults="transient@prefill:max=99",
                        max_step_retries=1)
    eng.submit(rng.integers(0, 64, (5,), dtype=np.int32), 3)
    with pytest.raises(TransientOpError):
        eng.run()
    assert eng.counters["retries"] == 2      # 1 retry per exhausted attempt


def test_arena_pressure_exact_tokens(reference):
    """Withheld pages squeeze admission/growth for whole steps; the
    scheduler absorbs it (delayed admission, preemption + exact
    recompute) and every stream still matches the unpressured run."""
    eng, rep = _run("seed=5;arena:pages=4,start=1,max=6")
    assert rep["summary"]["injected_faults"] == 6
    assert eng.alloc.held_pages == 0         # released after every step
    for a, b in zip(reference, _tokens(rep)):
        np.testing.assert_array_equal(a, b)
    for r in rep["requests"]:
        assert r["status"] == "finished"


def test_straggler_injection_counts():
    slept = []
    eng, rep = _run(None)                    # build geometry only for ref
    rng = np.random.default_rng(0)
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=8, temperature=0.0, seed=0,
                        backend="interpret", prefill_chunk=8,
                        faults="straggler@step:delay=0.5,max=2")
    eng.faults.sleep = slept.append          # no real 0.5s sleeps in CI
    eng.submit(rng.integers(0, 64, (5,), dtype=np.int32), 4)
    rep = eng.run()
    assert slept == [0.5, 0.5]
    assert rep["faults"] == {"straggler@step": 2}
    assert "step_p95_s" in rep["summary"]


# ---------------------------------------------------------------------------
# the flagship: mixed adversarial trace
# ---------------------------------------------------------------------------
def test_mixed_chaos_trace_exact_and_no_silent_loss(reference):
    eng, rep = _run(MIXED_PLAN)
    s = rep["summary"]
    assert s["fallbacks"] == 2 and s["retries"] == 1
    assert rep["faults"]["nan@decode"] == 2
    assert rep["faults"]["arena@arena"] == 3
    # no silent loss: every request terminal, every stream bit-exact
    assert len(rep["requests"]) == 3
    for r in rep["requests"]:
        assert r["status"] in ("finished", "shed")
    for a, b in zip(reference, _tokens(rep)):
        np.testing.assert_array_equal(a, b)
    # replay: the same plan injects the same faults and the same tokens
    eng2, rep2 = _run(MIXED_PLAN)
    assert rep2["faults"] == rep["faults"]
    for a, b in zip(_tokens(rep), _tokens(rep2)):
        np.testing.assert_array_equal(a, b)


def test_faults_disabled_matches_reference(reference):
    """PR-5 parity: a second fault-free run is bit-identical (the
    robustness machinery is pure overhead-free plumbing when off)."""
    _, rep = _run(None)
    for a, b in zip(reference, _tokens(rep)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# KV host-offload faults: the ladder degrades spill/restore to recompute
# ---------------------------------------------------------------------------
def _run_evict(faults_spec=None, **kw):
    """Forced-eviction geometry: 2 slots x 4 pages cannot hold two
    19-token prompts through 8 generated tokens, so the youngest runner
    is preempted mid-flight -- the spill/restore path every offload fault
    must degrade gracefully from."""
    rng = np.random.default_rng(0)
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=4, temperature=0.0, seed=0,
                        backend="interpret", prefill_chunk=8,
                        faults=faults_spec, **kw)
    for n in (19, 19):
        eng.submit(rng.integers(0, 64, (n,), dtype=np.int32), 8)
    return eng, eng.run()


@pytest.fixture(scope="module")
def evict_reference():
    _, rep = _run_evict(None)
    assert rep["summary"]["preemptions"] >= 1     # geometry really evicts
    return _tokens(rep)


@pytest.mark.slow
def test_offload_io_spill_fault_degrades_to_recompute(evict_reference):
    """A failed spill DMA means no host copy exists: the victim restarts
    through the classic recompute path, token-for-token equal."""
    eng, rep = _run_evict("offload_io@spill:max=99", kv_offload=True)
    s = rep["summary"]
    assert rep["faults"].get("offload_io@spill", 0) >= 1
    assert s["offload_spills"] == 0 and s["offload_restores"] == 0
    assert s["restarts_restored"] == 0 and s["restarts_recomputed"] >= 1
    for a, b in zip(evict_reference, _tokens(rep)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_offload_io_restore_fault_degrades_to_recompute(evict_reference):
    """The spill lands but the restore DMA fails: the stale spill is
    dropped and the SAME admission retries as a recompute -- no token
    drift, no wedged queue."""
    eng, rep = _run_evict("offload_io@restore:max=99", kv_offload=True)
    s = rep["summary"]
    assert rep["faults"].get("offload_io@restore", 0) >= 1
    assert s["offload_spills"] >= 1 and s["offload_restores"] == 0
    assert s["restarts_restored"] == 0 and s["restarts_recomputed"] >= 1
    assert eng.alloc.host_used_pages == 0          # nothing parked forever
    for a, b in zip(evict_reference, _tokens(rep)):
        np.testing.assert_array_equal(a, b)


@pytest.mark.slow
def test_offload_fault_free_restores_exactly(evict_reference):
    """Control for the two tests above: with no fault the same geometry
    restores instead of recomputing -- and still matches bit-for-bit."""
    eng, rep = _run_evict(None, kv_offload=True)
    s = rep["summary"]
    assert s["offload_spills"] >= 1 and s["offload_restores"] >= 1
    assert s["restarts_restored"] >= 1 and s["restarts_recomputed"] == 0
    for a, b in zip(evict_reference, _tokens(rep)):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# schedule quarantine
# ---------------------------------------------------------------------------
@pytest.fixture
def tmp_tune_cache(tmp_path):
    from repro.tune import cache as tcache
    path = str(tmp_path / "plans.json")
    prev_cache = flags.get("tune_cache")
    prev_mode = flags.get("tune_mode")
    flags.set_flag("tune_cache", path)
    tcache.reset_cache()
    yield path
    flags.set_flag("tune_cache", prev_cache)
    flags.set_flag("tune_mode", prev_mode)
    tcache.reset_cache()


def test_plan_cache_quarantine_roundtrip(tmp_tune_cache):
    from repro.tune import cache as tcache
    pc = tcache.get_cache()
    pc.store_schedule("k1", {"page_size": 32})
    assert pc.lookup_schedule("k1", ("page_size",)) is not None
    pc.quarantine("k1")
    assert pc.is_quarantined("k1")
    # miss, not the entry
    assert pc.lookup_schedule("k1", ("page_size",)) is None
    pc.store_schedule("k1", {"page_size": 64})
    # re-store refused
    assert pc.lookup_schedule("k1", ("page_size",)) is None
    # quarantine persists across a cache reload
    tcache.reset_cache()
    pc2 = tcache.get_cache()
    assert pc2.is_quarantined("k1")
    pc2.unquarantine("k1")
    assert not pc2.is_quarantined("k1")
    pc2.store_schedule("k1", {"page_size": 64})
    assert pc2.lookup_schedule("k1", ("page_size",)) is not None


def test_guard_trip_quarantines_decode_schedule(tmp_tune_cache):
    from repro.tune import cache as tcache
    flags.set_flag("tune_mode", "cached")
    eng, rep = _run("seed=1;nan@decode:max=1")
    assert eng._paged_sched_key is not None
    assert rep["quarantined"] == [eng._paged_sched_key]
    assert tcache.get_cache().is_quarantined(eng._paged_sched_key)
    # prefill-site trips fall back + count but blame no single schedule
    eng2, rep2 = _run("seed=1;nan@prefill:max=1")
    assert rep2["summary"]["fallbacks"] == 1
    assert rep2["quarantined"] == []


# ---------------------------------------------------------------------------
# checkpoint-write faults
# ---------------------------------------------------------------------------
def test_checkpoint_write_fault_raises_once(tmp_path):
    from repro.checkpoint.store import latest_step, save_checkpoint
    tree = {"w": jnp.ones((4,), jnp.float32)}
    faults.install("ckpt_io:max=1")
    try:
        with pytest.raises(OSError):
            save_checkpoint(str(tmp_path), 1, tree)
        assert latest_step(str(tmp_path)) is None    # nothing half-written
        save_checkpoint(str(tmp_path), 2, tree)      # plan exhausted
        assert latest_step(str(tmp_path)) == 2
    finally:
        faults.deactivate()


# ---------------------------------------------------------------------------
# SLO enforcement: deadline shedding
# ---------------------------------------------------------------------------
def test_deadline_shed_at_admission():
    t = [100.0]
    rng = np.random.default_rng(0)
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=8, temperature=0.0, seed=0,
                        backend="interpret", prefill_chunk=8,
                        enforce_deadlines=True)
    eng.sched.clock = lambda: t[0]
    eng.submit(rng.integers(0, 64, (5,), dtype=np.int32), 3,
               deadline=99.0)                        # already expired
    eng.submit(rng.integers(0, 64, (9,), dtype=np.int32), 3,
               deadline=10_000.0)
    rep = eng.run()
    stats = {r["status"] for r in rep["requests"]}
    assert stats == {"shed", "finished"}
    shed = [r for r in rep["requests"] if r["status"] == "shed"][0]
    assert shed["shed_reason"] == "deadline_missed"
    assert shed["new_tokens"] == 0                   # never charged a step
    assert rep["summary"]["shed"] == 1
    assert eng.alloc.free_pages == eng.alloc.n_pages  # nothing leaked


def test_deadline_shed_mid_decode_frees_slot():
    t = [0.0]
    rng = np.random.default_rng(0)
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=8, temperature=0.0, seed=0,
                        backend="interpret", prefill_chunk=8,
                        enforce_deadlines=True)
    eng.sched.clock = lambda: t[0]
    r0 = eng.submit(rng.integers(0, 64, (5,), dtype=np.int32), 8,
                    deadline=50.0)
    r1 = eng.submit(rng.integers(0, 64, (9,), dtype=np.int32), 8)
    eng.step(); eng.step()                           # both mid-decode
    assert r0.state == "running" and r0.n_generated > 0
    partial = list(r0.generated)
    t[0] = 60.0                                      # r0's SLO passes
    rep = eng.run()
    assert r0.state == "shed" and r0.slot == -1
    assert r0.generated == partial                   # stream never rewound
    assert r1.state == "finished" and r1.n_generated == 8
    assert rep["summary"]["shed"] == 1
    assert eng.alloc.free_pages == eng.alloc.n_pages


def test_deadlines_ignored_unless_enforced():
    """PR-5 compatibility: deadlines order admission (EDF) but never shed
    unless the engine opts in -- expired absolute deadlines are a legal
    pure-ordering input."""
    rng = np.random.default_rng(0)
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=8, temperature=0.0, seed=0,
                        backend="interpret", prefill_chunk=8)
    eng.submit(rng.integers(0, 64, (5,), dtype=np.int32), 3, deadline=50.0)
    rep = eng.run()
    assert rep["requests"][0]["status"] == "finished"
    assert rep["summary"]["shed"] == 0


# ---------------------------------------------------------------------------
# assert_invariants debug oracle (GEMMINI_CHECK)
# ---------------------------------------------------------------------------
def test_assert_invariants_default_and_env(monkeypatch):
    """Off by default (it is O(pages) of asserts on the hot loop);
    $GEMMINI_CHECK flips the default without code edits; an explicit
    argument always wins over the environment."""
    monkeypatch.delenv("GEMMINI_CHECK", raising=False)
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=8, backend="interpret")
    assert eng.assert_invariants is False
    monkeypatch.setenv("GEMMINI_CHECK", "1")
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=8, backend="interpret")
    assert eng.assert_invariants is True
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=8, backend="interpret",
                        assert_invariants=False)
    assert eng.assert_invariants is False


def test_assert_invariants_catches_corruption():
    """The knob really runs the allocator oracle at the step boundary: a
    simulated refcount leak makes the NEXT step raise, and the same
    corruption on an unchecked engine passes silently (the default path
    must stay assert-free)."""
    rng = np.random.default_rng(0)
    for checked in (True, False):
        eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                            n_pages=8, temperature=0.0, seed=0,
                            backend="interpret", prefill_chunk=8,
                            assert_invariants=checked)
        eng.submit(rng.integers(0, 64, (5,), dtype=np.int32), 4)
        eng.step()
        pages = eng.alloc.slot_pages(0)
        assert pages, "request should hold pages after one step"
        eng.alloc._ref[pages[0]] += 1          # simulate a leak
        if checked:
            with pytest.raises(AssertionError):
                eng.step()
        else:
            eng.step()                          # silently tolerated


def test_chaos_run_clean_under_invariant_oracle():
    """The flagship chaos plan keeps every allocator invariant at every
    step boundary (the chaos suite doubles as a lifecycle audit)."""
    _, rep = _run(MIXED_PLAN, assert_invariants=True)
    assert all(r["status"] in ("finished", "shed") for r in rep["requests"])
