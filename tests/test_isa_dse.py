"""ISA timing model + DSE engine: reproduce the paper's findings in tests.

The analytic reproduction runs at the paper's NATIVE scale (16x16 int8
array, 64 KiB scratchpad, 128-bit bus -- config.PAPER_DESIGN_POINTS);
the TPU-scaled DESIGN_POINTS drive the Pallas kernels instead.
"""

import pytest

from repro.core import dse, isa
from repro.core.config import PAPER_DESIGN_POINTS, Dataflow, GemminiConfig
from repro.core.tiling import plan_gemm

BASE = PAPER_DESIGN_POINTS[1]


def test_instruction_stream_traffic_matches_plan():
    plan = plan_gemm(BASE, 512, 512, 512)
    loads = stores = macs = 0
    for ins in isa.instruction_stream(plan, BASE):
        if ins.op is isa.Op.MVIN:
            loads += ins.bytes
        elif ins.op is isa.Op.MVOUT:
            stores += ins.bytes
        elif ins.op is isa.Op.COMPUTE:
            macs += ins.macs
    assert macs == plan.macs
    assert loads == plan.hbm_read_bytes
    assert stores == plan.hbm_write_bytes


def test_ws_loads_less_than_os():
    """WS preloads B once per (n,k) tile -- at identical tile shapes it
    always moves no more HBM bytes than OS (the dataflow's reuse)."""
    caps = dict(max_tile_m=64, max_tile_n=64, max_tile_k=256,
                accumulator_bytes=64 * 1024)
    cfg_os = BASE.replace(**caps)
    cfg_ws = BASE.replace(dataflow=Dataflow.WS, **caps)
    p_os = plan_gemm(cfg_os, 8192, 512, 512)
    p_ws = plan_gemm(cfg_ws, 8192, 512, 512)
    assert (p_ws.tile_m, p_ws.tile_n, p_ws.tile_k) == \
        (p_os.tile_m, p_os.tile_n, p_os.tile_k)
    assert p_ws.hbm_read_bytes < p_os.hbm_read_bytes


def test_bus_width_finding():
    """Design point 9: the 16x16 machine is latency-bound (16 in-flight
    16B row requests / 80-cycle round trip = 3.2 B/cyc < any bus), so
    halving the bus width does not change performance at all."""
    plan = plan_gemm(BASE, 1024, 1024, 1024)
    t_wide = isa.simulate(plan, BASE, isa.ROCKET)
    t_narrow = isa.simulate(plan, BASE, isa.NARROW_BUS)
    assert t_wide.bottleneck in ("LOAD", "STORE")
    assert t_narrow.total_cycles == pytest.approx(t_wide.total_cycles,
                                                  rel=1e-6)


def test_dim_doubling_boosts_mlp_2x_to_4x():
    """Design point 5: 2x array dim doubles the effective (latency-bound)
    bandwidth and quadruples compute -> 2-4x on MLPs (paper Fig 7b)."""
    for name in ("mlp1", "mlp3", "mlp4"):
        wl = dse.PAPER_MLPS[name]
        base = dse.evaluate(PAPER_DESIGN_POINTS[1], wl, isa.ROCKET)
        big = dse.evaluate(PAPER_DESIGN_POINTS[5], wl, isa.ROCKET)
        speedup = base["total_cycles"] / big["total_cycles"]
        assert 1.8 <= speedup <= 4.5, (name, speedup)


def test_mobilenet_is_host_limited():
    """The paper's Amdahl finding: depthwise convs + im2col on the host
    dominate accelerated MobileNet; a beefier host (BOOM, point 10) helps
    MobileNet more than anything else does."""
    wl = dse.mobilenet_v1()
    r = dse.evaluate(PAPER_DESIGN_POINTS[1], wl, isa.ROCKET)
    assert r["host_cycles"] > r["engine_cycles"]
    r_boom = dse.evaluate(PAPER_DESIGN_POINTS[1], wl, isa.BOOM, host="boom")
    assert r_boom["total_cycles"] < r["total_cycles"] * 0.75


def test_mobilenet_more_host_bound_than_resnet():
    """ResNet-152 has the largest 1x1 fraction -> least host-limited
    (the paper: 'Resnet-152 ... performed better in general')."""
    def host_share(wl):
        r = dse.evaluate(PAPER_DESIGN_POINTS[1], wl, isa.ROCKET)
        return r["host_cycles"] / r["total_cycles"]

    mob = host_share(dse.mobilenet_v1())
    r50 = host_share(dse.resnet(50))
    r152 = host_share(dse.resnet(152))
    assert mob > r50 > 0
    assert r152 <= r50 + 1e-9


def test_scratchpad_scaling_helps_mlps_more_than_dnns():
    """Design point 7 vs 1: bigger scratchpad helps MLPs (not host-bound);
    its effect on MobileNet is capped by the host term (paper Fig 7a)."""
    mlp = dse.PAPER_MLPS["mlp1"]
    mob = dse.mobilenet_v1()
    b1 = dse.run_design_points(mlp, points=(1, 7))
    m1 = dse.run_design_points(mob, points=(1, 7))
    mlp_gain = b1[0].total_cycles / b1[1].total_cycles
    mob_gain = m1[0].total_cycles / m1[1].total_cycles
    assert mlp_gain >= mob_gain * 0.99


def test_tiling_fit_mlp4_beats_mlp3():
    """Fig 7b: power-of-two MLP4 maps onto the tiling factors better than
    MLP3 (dims 257/2048) -- higher utilization."""
    r3 = dse.evaluate(PAPER_DESIGN_POINTS[1], dse.PAPER_MLPS["mlp3"],
                      isa.ROCKET)
    r4 = dse.evaluate(PAPER_DESIGN_POINTS[1], dse.PAPER_MLPS["mlp4"],
                      isa.ROCKET)
    assert r4["utilization"] > r3["utilization"]


def test_32bit_inputs_hurt():
    """Design point 4: 32-bit inputs quadruple traffic -> slower (Fig 7)."""
    wl = dse.PAPER_MLPS["mlp2"]
    r8 = dse.evaluate(PAPER_DESIGN_POINTS[1], wl, isa.ROCKET)
    r32 = dse.evaluate(PAPER_DESIGN_POINTS[4], wl, isa.ROCKET)
    assert r32["total_cycles"] > r8["total_cycles"] * 1.5


def test_whole_network_speedup_two_orders_on_mlps():
    """Paper headline: 'two to three orders of magnitude speedup on MLPs'
    vs the CPU baseline (~1 MAC/cycle cache-blocked)."""
    wl = dse.PAPER_MLPS["mlp1"]
    r = dse.evaluate(PAPER_DESIGN_POINTS[1], wl, isa.ROCKET)
    cpu_cycles = sum(2.0 * g.m * g.n * g.k * g.repeats for g in wl.gemms)
    speedup = cpu_cycles / r["total_cycles"]
    assert 50 <= speedup <= 2000, speedup


def test_all_design_points_run():
    res = dse.run_design_points(dse.PAPER_MLPS["mlp2"])
    assert len(res) == 10
    assert all(r.total_cycles > 0 for r in res)
