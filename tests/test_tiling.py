"""Tiling solver: the generator's "header file" must always be legal."""

import math

import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # container has no hypothesis
    from _hyp import given, settings, strategies as st

from repro.core.config import Dataflow, GemminiConfig, bytes_of
from repro.core.tiling import padded_shape, plan_gemm

DIMS = st.integers(min_value=1, max_value=4096)


@settings(max_examples=200, deadline=None)
@given(m=DIMS, n=DIMS, k=DIMS,
       df=st.sampled_from([Dataflow.OS, Dataflow.WS]),
       bias=st.booleans())
def test_plan_fits_budgets_and_covers(m, n, k, df, bias):
    cfg = GemminiConfig(dataflow=df)
    plan = plan_gemm(cfg, m, n, k, has_bias=bias)
    # tiles are dim-aligned
    assert plan.tile_m % cfg.dim == 0
    assert plan.tile_n % cfg.dim == 0
    assert plan.tile_k % cfg.dim == 0
    # grid covers the padded problem exactly
    gm, gn, gk = plan.grid
    assert gm * plan.tile_m == plan.m >= m
    assert gn * plan.tile_n == plan.n >= n
    assert gk * plan.tile_k == plan.k >= k
    # budgets respected (the scratchpad/accumulator contract)
    assert plan.vmem_streamed_bytes <= cfg.scratchpad_bytes
    assert plan.vmem_resident_bytes <= cfg.accumulator_bytes
    # utilization = useful / padded macs in (0, 1]
    assert 0.0 < plan.utilization <= 1.0
    assert plan.macs == plan.m * plan.n * plan.k


@settings(max_examples=50, deadline=None)
@given(m=DIMS, n=DIMS, k=DIMS)
def test_bigger_scratchpad_never_hurts_intensity(m, n, k):
    """The paper's design point 7: 4x scratchpad -> >= arithmetic intensity.

    2% tolerance: AI counts PADDED macs, and different tile_k splits can
    pad k differently (e.g. k=3400: one 3456-wide tile vs two 1792-wide
    steps padding to 3584), shifting AI by a fraction of a percent without
    any real reuse change.
    """
    small = GemminiConfig(scratchpad_bytes=8 << 20, accumulator_bytes=4 << 20)
    big = GemminiConfig(scratchpad_bytes=32 << 20, accumulator_bytes=16 << 20)
    p_small = plan_gemm(small, m, n, k)
    p_big = plan_gemm(big, m, n, k)
    assert p_big.arithmetic_intensity >= p_small.arithmetic_intensity * 0.98


def test_padded_shape_matches_paper_zero_padding():
    cfg = GemminiConfig(dim=128)
    assert padded_shape(cfg, 1, 1, 1) == (128, 128, 128)
    assert padded_shape(cfg, 128, 256, 384) == (128, 256, 384)
    assert padded_shape(cfg, 129, 257, 300) == (256, 384, 384)


def test_dataflow_residency_difference():
    """OS keeps C resident; WS keeps B resident + revisits C."""
    cfg_os = GemminiConfig(dataflow=Dataflow.OS)
    cfg_ws = GemminiConfig(dataflow=Dataflow.WS)
    p_os = plan_gemm(cfg_os, 2048, 2048, 2048)
    p_ws = plan_gemm(cfg_ws, 2048, 2048, 2048)
    acc_b = bytes_of(cfg_os.acc_dtype)
    assert p_os.vmem_resident_bytes == p_os.tile_m * p_os.tile_n * acc_b
    assert p_ws.vmem_resident_bytes > p_ws.tile_m * p_ws.tile_n * acc_b

    # WS reads B once per (n, k) tile; OS re-reads per m-step too
    in_b = bytes_of(cfg_os.input_dtype)
    gm, gn, gk = p_ws.grid
    ws_b_reads = gn * gk * p_ws.tile_k * p_ws.tile_n * in_b
    assert ws_b_reads <= p_ws.hbm_read_bytes


def test_dataflow_mismatch_rejected():
    cfg = GemminiConfig(dataflow=Dataflow.OS)
    with pytest.raises(ValueError):
        plan_gemm(cfg, 128, 128, 128, dataflow=Dataflow.WS)


def test_both_dataflow_runtime_selectable():
    cfg = GemminiConfig(dataflow=Dataflow.BOTH)
    p1 = plan_gemm(cfg, 512, 512, 512, dataflow=Dataflow.OS)
    p2 = plan_gemm(cfg, 512, 512, 512, dataflow=Dataflow.WS)
    assert p1.dataflow is Dataflow.OS and p2.dataflow is Dataflow.WS


def test_tile_caps_respected():
    cfg = GemminiConfig(max_tile_m=128, max_tile_n=256, max_tile_k=128)
    p = plan_gemm(cfg, 4096, 4096, 4096)
    assert p.tile_m <= 128 and p.tile_n <= 256 and p.tile_k <= 128


def test_minimal_tile_must_fit():
    with pytest.raises(ValueError):
        GemminiConfig(dim=1024, scratchpad_bytes=1 << 20)


@pytest.mark.parametrize("shape", [(100, 4000, 1000), (1068, 4000, 1000),
                                   (1359, 4000, 1000), (1844, 300, 700)])
@pytest.mark.parametrize("df", [Dataflow.OS, Dataflow.WS])
def test_ragged_snap_never_overpads_past_dim_rounding(shape, df):
    """Regression: snap() used to pick tiles not dividing the dim-rounded
    problem, so the plan's padded dims exceeded padded_shape()'s (a wasted
    full tile row and a plan/legalization disagreement). E.g. M=1068 padded
    to 1280 instead of 1152."""
    m, n, k = shape
    cfg = GemminiConfig(dataflow=df)
    plan = plan_gemm(cfg, m, n, k)
    assert (plan.m, plan.n, plan.k) == padded_shape(cfg, m, n, k)
    gm, gn, gk = plan.grid
    assert gm * plan.tile_m == plan.m
    assert gn * plan.tile_n == plan.n
    assert gk * plan.tile_k == plan.k


def test_ragged_ops_gemm_agrees_with_plan(rng):
    """ctx.gemm's padding legalization and the plan agree on ragged shapes
    (the interpret kernel would shape-error on any mismatch)."""
    import jax.numpy as jnp
    from repro.core.context import ExecutionContext
    from repro.kernels import ref
    m, n, k = 100, 4000, 1000
    for df in (Dataflow.OS, Dataflow.WS):
        cfg = GemminiConfig(dataflow=df)
        a = jnp.asarray(rng.integers(-128, 128, (m, k)), jnp.int8)
        b = jnp.asarray(rng.integers(-128, 128, (k, n)), jnp.int8)
        y = ExecutionContext(cfg=cfg, backend="interpret").gemm(
            a, b, None, shift=8)
        yr = ref.gemm_ref(a, b, None, acc_dtype=jnp.int32,
                          out_dtype=jnp.int8, shift=8)
        assert y.shape == (m, n)
        assert bool(jnp.all(y == yr))


def test_make_plan_rejects_illegal_tiles():
    from repro.core.tiling import make_plan
    cfg = GemminiConfig()
    with pytest.raises(ValueError):
        make_plan(cfg, 256, 256, 256, 100, 128, 128)       # not dim-aligned
    with pytest.raises(ValueError):
        make_plan(cfg, 8192, 8192, 8192, 8192, 8192, 8192)  # busts budgets
