"""Tiling solver: the generator's "header file" must always be legal."""

import math

import pytest
from hypothesis import given, settings, strategies as st

from repro.core.config import Dataflow, GemminiConfig, bytes_of
from repro.core.tiling import padded_shape, plan_gemm

DIMS = st.integers(min_value=1, max_value=4096)


@settings(max_examples=200, deadline=None)
@given(m=DIMS, n=DIMS, k=DIMS,
       df=st.sampled_from([Dataflow.OS, Dataflow.WS]),
       bias=st.booleans())
def test_plan_fits_budgets_and_covers(m, n, k, df, bias):
    cfg = GemminiConfig(dataflow=df)
    plan = plan_gemm(cfg, m, n, k, has_bias=bias)
    # tiles are dim-aligned
    assert plan.tile_m % cfg.dim == 0
    assert plan.tile_n % cfg.dim == 0
    assert plan.tile_k % cfg.dim == 0
    # grid covers the padded problem exactly
    gm, gn, gk = plan.grid
    assert gm * plan.tile_m == plan.m >= m
    assert gn * plan.tile_n == plan.n >= n
    assert gk * plan.tile_k == plan.k >= k
    # budgets respected (the scratchpad/accumulator contract)
    assert plan.vmem_streamed_bytes <= cfg.scratchpad_bytes
    assert plan.vmem_resident_bytes <= cfg.accumulator_bytes
    # utilization = useful / padded macs in (0, 1]
    assert 0.0 < plan.utilization <= 1.0
    assert plan.macs == plan.m * plan.n * plan.k


@settings(max_examples=50, deadline=None)
@given(m=DIMS, n=DIMS, k=DIMS)
def test_bigger_scratchpad_never_hurts_intensity(m, n, k):
    """The paper's design point 7: 4x scratchpad -> >= arithmetic intensity.

    2% tolerance: AI counts PADDED macs, and different tile_k splits can
    pad k differently (e.g. k=3400: one 3456-wide tile vs two 1792-wide
    steps padding to 3584), shifting AI by a fraction of a percent without
    any real reuse change.
    """
    small = GemminiConfig(scratchpad_bytes=8 << 20, accumulator_bytes=4 << 20)
    big = GemminiConfig(scratchpad_bytes=32 << 20, accumulator_bytes=16 << 20)
    p_small = plan_gemm(small, m, n, k)
    p_big = plan_gemm(big, m, n, k)
    assert p_big.arithmetic_intensity >= p_small.arithmetic_intensity * 0.98


def test_padded_shape_matches_paper_zero_padding():
    cfg = GemminiConfig(dim=128)
    assert padded_shape(cfg, 1, 1, 1) == (128, 128, 128)
    assert padded_shape(cfg, 128, 256, 384) == (128, 256, 384)
    assert padded_shape(cfg, 129, 257, 300) == (256, 384, 384)


def test_dataflow_residency_difference():
    """OS keeps C resident; WS keeps B resident + revisits C."""
    cfg_os = GemminiConfig(dataflow=Dataflow.OS)
    cfg_ws = GemminiConfig(dataflow=Dataflow.WS)
    p_os = plan_gemm(cfg_os, 2048, 2048, 2048)
    p_ws = plan_gemm(cfg_ws, 2048, 2048, 2048)
    acc_b = bytes_of(cfg_os.acc_dtype)
    assert p_os.vmem_resident_bytes == p_os.tile_m * p_os.tile_n * acc_b
    assert p_ws.vmem_resident_bytes > p_ws.tile_m * p_ws.tile_n * acc_b

    # WS reads B once per (n, k) tile; OS re-reads per m-step too
    in_b = bytes_of(cfg_os.input_dtype)
    gm, gn, gk = p_ws.grid
    ws_b_reads = gn * gk * p_ws.tile_k * p_ws.tile_n * in_b
    assert ws_b_reads <= p_ws.hbm_read_bytes


def test_dataflow_mismatch_rejected():
    cfg = GemminiConfig(dataflow=Dataflow.OS)
    with pytest.raises(ValueError):
        plan_gemm(cfg, 128, 128, 128, dataflow=Dataflow.WS)


def test_both_dataflow_runtime_selectable():
    cfg = GemminiConfig(dataflow=Dataflow.BOTH)
    p1 = plan_gemm(cfg, 512, 512, 512, dataflow=Dataflow.OS)
    p2 = plan_gemm(cfg, 512, 512, 512, dataflow=Dataflow.WS)
    assert p1.dataflow is Dataflow.OS and p2.dataflow is Dataflow.WS


def test_tile_caps_respected():
    cfg = GemminiConfig(max_tile_m=128, max_tile_n=256, max_tile_k=128)
    p = plan_gemm(cfg, 4096, 4096, 4096)
    assert p.tile_m <= 128 and p.tile_n <= 256 and p.tile_k <= 128


def test_minimal_tile_must_fit():
    with pytest.raises(ValueError):
        GemminiConfig(dim=1024, scratchpad_bytes=1 << 20)
