"""ExecutionContext dispatch: registry, tune-mode scoping, deprecation
shims, the SSD fused epilogue/final-state contract, and the chunked-gather
kv_pages static bound. The mesh'd (shard_map) path is covered by the
multi-device subprocess test in test_sharding_dryrun.py."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core import context, flags
from repro.core.config import Activation, GemminiConfig
from repro.core.context import ExecutionContext
from repro.core.generator import elaborate
from repro.kernels import ops, ref
from repro.models import ssm


@pytest.fixture(autouse=True)
def _reset_flags():
    flags.reset()
    yield
    flags.reset()


def _ints(rng, shape, lo=-128, hi=128, dtype=jnp.int8):
    return jnp.asarray(rng.integers(lo, hi, shape), dtype)


# ---------------------------------------------------------------------------
# construction / registry
# ---------------------------------------------------------------------------
def test_context_validates_fields():
    with pytest.raises(ValueError):
        ExecutionContext(backend="mosaic")
    with pytest.raises(ValueError):
        ExecutionContext(tune_mode="sometimes")


def test_context_is_hashable_value():
    a = ExecutionContext(cfg=GemminiConfig(), backend="interpret")
    b = ExecutionContext(cfg=GemminiConfig(), backend="interpret")
    assert a == b and hash(a) == hash(b)
    assert a.with_backend("xla") != a


def test_registry_lists_every_op_and_rejects_unknown():
    ctx = ExecutionContext()
    have = context.registered_ops()
    for op in ("gemm", "matmul", "conv2d", "flash_attention",
               "paged_attention", "paged_prefill_attention", "ssd"):
        assert op in have
        assert callable(getattr(ctx, op))
    with pytest.raises(AttributeError):
        ctx.winograd
    with pytest.raises(ValueError):
        context.register_op("gemm")(lambda ctx: None)   # duplicate


def test_engine_ops_require_cfg():
    with pytest.raises(ValueError):
        ExecutionContext(backend="interpret").gemm(
            jnp.zeros((8, 8), jnp.int8), jnp.zeros((8, 8), jnp.int8))


def test_as_context_protocol():
    inst = elaborate(GemminiConfig(), "interpret")
    assert context.as_context(inst) is inst.ctx
    ctx = ExecutionContext(backend="xla")
    assert context.as_context(ctx) is ctx
    assert context.as_context(None).backend == "xla"
    with pytest.raises(TypeError):
        context.as_context(object())


def test_instance_with_mesh_derives_ctx():
    inst = elaborate(GemminiConfig(), "interpret")
    mesh = jax.make_mesh((1,), ("data",))
    m = inst.with_mesh(mesh)
    assert m.ctx.mesh is mesh and m.ctx.n_shards == 1
    assert not m.ctx.sharded                   # 1 shard: plain dispatch
    assert inst.ctx.mesh is None               # original untouched


# ---------------------------------------------------------------------------
# numerics: ctx dispatch == kernel impls == refs
# ---------------------------------------------------------------------------
def test_ctx_gemm_matches_ref(rng):
    cfg = GemminiConfig()
    ctx = ExecutionContext(cfg=cfg, backend="interpret")
    a, b = _ints(rng, (100, 72)), _ints(rng, (72, 40))
    d = _ints(rng, (1, 40), -500, 500, jnp.int32)
    y = ctx.gemm(a, b, d, shift=7, activation=Activation.RELU)
    yr = ref.gemm_ref(a, b, d, acc_dtype=jnp.int32, out_dtype=jnp.int8,
                      shift=7, activation=Activation.RELU)
    assert bool(jnp.all(y == yr))


def test_ctx_flash_attention_default_cfg(rng):
    """cfg=None is legal for the attention ops (bf16 engine default)."""
    ctx = ExecutionContext(backend="interpret")
    q = jnp.asarray(rng.standard_normal((1, 64, 4, 16)), jnp.float32)
    kv = jnp.asarray(rng.standard_normal((1, 64, 2, 16)), jnp.float32)
    y = ctx.flash_attention(q, kv, kv, causal=True)
    yr = ctx.with_backend("xla").flash_attention(q, kv, kv, causal=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_ctx_tune_mode_scoped_per_dispatch(rng, tmp_path):
    """ctx.tune_mode overrides the process flag only for the dispatch:
    the cached-mode context consults the plan cache while the process
    stays in off mode before and after."""
    flags.set_flag("tune_cache", str(tmp_path / "plans.json"))
    from repro.tune import cache as tcache
    tcache.reset_cache()
    cfg = GemminiConfig()
    a, b = _ints(rng, (64, 64)), _ints(rng, (64, 64))
    assert flags.get("tune_mode") == "off"
    pc = tcache.get_cache()
    m0 = pc.misses
    ctx = ExecutionContext(cfg=cfg, backend="interpret", tune_mode="cached")
    y = ctx.gemm(a, b, None, shift=4)
    assert pc.misses == m0 + 1            # the cache WAS consulted
    assert flags.get("tune_mode") == "off"   # scope restored
    off = ExecutionContext(cfg=cfg, backend="interpret", tune_mode="off")
    assert bool(jnp.all(off.gemm(a, b, None, shift=4) == y))
    tcache.reset_cache()


# ---------------------------------------------------------------------------
# the old ops.*(backend=...) shims are GONE (PR 7, grace period over)
# ---------------------------------------------------------------------------
def test_legacy_shims_removed(rng):
    """The seven PR-5 deprecation shims no longer exist on ops; the
    *_impl entries (the ExecutionContext dispatch surface) remain, and
    lint rule GL506 forbids rebinding the legacy names."""
    for name in ("gemm", "matmul", "conv2d", "flash_attention",
                 "paged_attention", "paged_prefill_attention", "ssd"):
        assert not hasattr(ops, name), f"legacy shim ops.{name} resurfaced"
        assert hasattr(ops, name + "_impl")
    # the impl surface stays warning-free and live
    cfg = GemminiConfig(input_dtype="fp32", acc_dtype="fp32",
                        output_dtype="fp32")
    a = jnp.asarray(rng.standard_normal((16, 8)), jnp.float32)
    b = jnp.asarray(rng.standard_normal((8, 8)), jnp.float32)
    ops.gemm_impl(a, b, cfg=cfg)


# ---------------------------------------------------------------------------
# ssd: fused epilogue / final state / initial_state demotion
# ---------------------------------------------------------------------------
def _ssd_inputs(rng, bsz=1, t=48, h=2, p=8, g=1, n=16):
    x = jnp.asarray(rng.standard_normal((bsz, t, h, p)), jnp.float32)
    dt = jnp.abs(jnp.asarray(rng.standard_normal((bsz, t, h)) * 0.5,
                             jnp.float32)) + 0.01
    a_log = jnp.asarray(rng.standard_normal((h,)) * 0.3, jnp.float32)
    b = jnp.asarray(rng.standard_normal((bsz, t, g, n)) * 0.3, jnp.float32)
    c = jnp.asarray(rng.standard_normal((bsz, t, g, n)) * 0.3, jnp.float32)
    d_skip = jnp.asarray(rng.standard_normal((h,)) * 0.5, jnp.float32)
    return x, dt, a_log, b, c, d_skip


def test_ctx_ssd_kernel_final_state_fused(rng):
    """The interpret path returns the kernel-emitted final state (no XLA
    recompute) and it matches the reference handoff state."""
    x, dt, a_log, b, c, d_skip = _ssd_inputs(rng)
    ctx = ExecutionContext(backend="interpret")
    y, fs = ctx.ssd(x, dt, a_log, b, c, d_skip=d_skip, chunk=16,
                    return_final_state=True)
    y_ref, fs_ref = ctx.with_backend("xla").ssd(
        x, dt, a_log, b, c, d_skip=d_skip, chunk=16,
        return_final_state=True)
    rel = float(jnp.max(jnp.abs(y - y_ref))) / float(jnp.max(jnp.abs(y_ref)))
    assert rel < 1e-4
    np.testing.assert_allclose(np.asarray(fs), np.asarray(fs_ref),
                               rtol=1e-4, atol=1e-5)


def test_ssd_kernel_is_single_pallas_call_with_fused_epilogue(rng):
    """Fusion audit acceptance: one pallas_call lowers the whole SSD --
    d_skip epilogue and final-state emission included; no post-kernel
    XLA add/recompute pass."""
    x, dt, a_log, b, c, d_skip = _ssd_inputs(rng)

    def run(x, dt, b, c):
        return ops.ssd_impl(x, dt, a_log, b, c, d_skip=d_skip, chunk=16,
                            backend="interpret", return_final_state=True)

    jaxpr = jax.make_jaxpr(run)(x, dt, b, c)
    flat = jaxpr.jaxpr
    n_calls = sum(1 for e in flat.eqns if "pallas_call" in str(e.primitive))
    assert n_calls == 1
    # no einsum/dot epilogue after the kernel: every dot lives in-kernel
    assert not any("dot_general" in str(e.primitive) for e in flat.eqns)


def test_ctx_ssd_initial_state_demotes_to_xla(rng):
    """A resumed chunk (initial_state != None) runs the xla reference on
    every backend -- bit-identical to calling the reference directly."""
    x, dt, a_log, b, c, d_skip = _ssd_inputs(rng, t=32)
    init = jnp.asarray(rng.standard_normal((1, 2, 16, 8)), jnp.float32)
    ctx = ExecutionContext(backend="interpret")
    y = ctx.ssd(x, dt, a_log, b, c, d_skip=d_skip, chunk=16,
                initial_state=init)
    yr = ssm.ssd_chunked_xla(x, dt, a_log, b, c, d_skip=d_skip, chunk=16,
                             initial_state=init)
    assert bool(jnp.all(y == yr))


# ---------------------------------------------------------------------------
# chunked-gather kv_pages static bound
# ---------------------------------------------------------------------------
@pytest.mark.parametrize("backend", ["xla", "interpret"])
def test_paged_prefill_kv_pages_bound_exact(rng, backend):
    """Slicing the table to the admission-time page bound is a pure
    dead-key elision: output exactly matches the capacity-wide gather."""
    h, kvh, d, page, mp = 4, 2, 16, 8, 12
    start, tq = 8, 8                          # chunk 2 of a 16-token prompt
    kv_pages = 2                              # covers start + tq = 16 keys
    pool_shape = (kvh, mp + 1, page, d)
    k_pool = jnp.asarray(rng.standard_normal(pool_shape), jnp.float32)
    v_pool = jnp.asarray(rng.standard_normal(pool_shape), jnp.float32)
    table = jnp.asarray(rng.permutation(mp).astype(np.int32))
    q = jnp.asarray(rng.standard_normal((1, tq, h, d)), jnp.float32)
    ctx = ExecutionContext(backend=backend)
    full = ctx.paged_prefill_attention(q, k_pool, v_pool, table,
                                       jnp.int32(start))
    tight = ctx.paged_prefill_attention(q, k_pool, v_pool, table,
                                        jnp.int32(start), kv_pages=kv_pages)
    np.testing.assert_array_equal(np.asarray(tight), np.asarray(full))


def test_paged_prefill_kv_pages_cuts_gathered_keys():
    """The xla twin's gather really shrinks: the contracted key axis is
    the 128-clamped kv_pages * page width, not the table capacity."""
    h, kvh, d, page, mp = 2, 1, 8, 8, 32     # capacity 256 keys
    pool = jnp.zeros((kvh, mp + 1, page, d), jnp.float32)
    table = jnp.arange(mp, dtype=jnp.int32)
    q = jnp.zeros((1, 8, h, d), jnp.float32)
    ctx = ExecutionContext(backend="xla")

    def width(kv_pages):
        jaxpr = jax.make_jaxpr(
            lambda q, k, v: ctx.paged_prefill_attention(
                q, k, v, table, jnp.int32(0), kv_pages=kv_pages))(
            q, pool, pool)
        # widest KV-shaped intermediate = the gathered/padded key axis
        return max(v.aval.shape[1] for e in jaxpr.jaxpr.eqns
                   for v in e.outvars
                   if len(v.aval.shape) == 4 and v.aval.shape[0] == 1
                   and v.aval.shape[-1] == d)

    assert width(None) == mp * page           # capacity-wide gather
    assert width(2) == 128                    # 16 keys, 128-clamped block
