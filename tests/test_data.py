"""Data pipeline: determinism, topology independence, sharded assembly."""

import jax
import jax.numpy as jnp
import numpy as np

from repro.data import SyntheticLM, SyntheticLMConfig, make_global_batch
from repro.launch.mesh import make_mesh


def _cfg(**kw):
    base = dict(vocab=1000, seq=32, global_batch=8, seed=3)
    base.update(kw)
    return SyntheticLMConfig(**base)


def test_rows_deterministic():
    g1 = SyntheticLM(_cfg())
    g2 = SyntheticLM(_cfg())
    np.testing.assert_array_equal(g1.row(5, 3), g2.row(5, 3))
    # different steps / rows differ
    assert not np.array_equal(g1.row(5, 3), g1.row(6, 3))
    assert not np.array_equal(g1.row(5, 3), g1.row(5, 4))


def test_rows_within_vocab():
    gen = SyntheticLM(_cfg(vocab=50))
    r = gen.row(0, 0)
    assert r.min() >= 0 and r.max() < 50


def test_topology_independence():
    """The same global batch regardless of how hosts split the rows --
    what makes elastic restarts data-transparent."""
    gen = SyntheticLM(_cfg())
    full = gen.host_batch(2, range(0, 8))["tokens"]
    h0 = gen.host_batch(2, range(0, 4))["tokens"]
    h1 = gen.host_batch(2, range(4, 8))["tokens"]
    np.testing.assert_array_equal(np.concatenate([h0, h1]), full)


def test_multi_codebook_rows():
    gen = SyntheticLM(_cfg(n_codebooks=4))
    r = gen.row(0, 0)
    assert r.shape == (32, 4)


def test_markov_structure_learnable():
    """Successor entropy must be far below uniform (the pipeline produces
    predictable structure, not noise)."""
    gen = SyntheticLM(_cfg(vocab=64, seq=4096, branching=2))
    r = gen.row(0, 0)
    # count distinct successors per state
    succ = {}
    for a, b in zip(r[:-1], r[1:]):
        succ.setdefault(int(a), set()).add(int(b))
    avg_succ = np.mean([len(v) for v in succ.values()])
    assert avg_succ <= 2 * 2 + 1   # ~branching (+ doc breaks), << vocab


def test_make_global_batch_sharded():
    gen = SyntheticLM(_cfg())
    mesh = make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))
    batch = make_global_batch(gen, 0, sh)
    assert batch["tokens"].shape == (8, 32)
    np.testing.assert_array_equal(
        np.asarray(batch["tokens"]),
        gen.host_batch(0, range(8))["tokens"])


def test_extra_embeds_stub():
    gen = SyntheticLM(_cfg())
    mesh = make_mesh((1,), ("data",))
    sh = jax.sharding.NamedSharding(
        mesh, jax.sharding.PartitionSpec("data", None))
    batch = make_global_batch(gen, 0, sh, extra_embed_dim=16,
                              extra_tokens=5)
    assert batch["extra_embeds"].shape == (8, 5, 16)
    assert bool(jnp.all(jnp.isfinite(batch["extra_embeds"])))
