"""Loop-aware HLO analyzer: the roofline's source of truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_hlo, parse_module
from repro.analysis import roofline


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scan_flops_scaled_by_trip_count():
    def scan10(x, w):
        def f(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(f, x, None, length=10)
        return y

    def unrolled10(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    cs = _compile(scan10, x, w)
    cu = _compile(unrolled10, x, w)
    rs, ru = analyze_hlo(cs.as_text()), analyze_hlo(cu.as_text())
    analytic_dots = 10 * 2 * 256 ** 3

    # XLA's builtin undercounts the scan ~10x -- the bug we fix:
    ca = cs.cost_analysis()
    if isinstance(ca, list):      # jax < 0.6 returns one dict per device
        ca = ca[0]
    assert ca["flops"] < 0.2 * analytic_dots
    # our analyzer agrees with both the unrolled version and the math:
    assert abs(rs.flops - ru.flops) / ru.flops < 0.01
    assert abs(rs.flops - analytic_dots) / analytic_dots < 0.01
    assert rs.n_while == 1 and rs.max_trip == 10


def test_nested_scan_multipliers():
    def nested(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = analyze_hlo(_compile(nested, x, w).as_text())
    analytic = 3 * 4 * 2 * 128 ** 3
    assert abs(r.flops - analytic) / analytic < 0.02


def test_dot_general_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    r = analyze_hlo(_compile(f, a, b).as_text())
    analytic = 2 * 4 * 64 * 32 * 16
    assert abs(r.flops - analytic) / analytic < 0.01


def test_bytes_sane():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    r = analyze_hlo(_compile(f, a, b).as_text())
    io_bytes = 3 * 512 * 512 * 4
    assert io_bytes <= r.bytes <= 2 * io_bytes


def test_collectives_multiplied(run_subprocess):
    code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.analysis.hlo import analyze_hlo
from repro.launch.mesh import activate_mesh, make_mesh

mesh = make_mesh((8,), ("model",))
def f(x, w):
    def body(c, _):
        y = jax.lax.with_sharding_constraint(
            c @ w, NamedSharding(mesh, P(None, "model")))
        y = jax.lax.with_sharding_constraint(
            y @ w.T, NamedSharding(mesh, P()))
        return y, None
    y, _ = jax.lax.scan(body, x, None, length=5)
    return y

x = jax.ShapeDtypeStruct((128, 1024), jnp.float32,
                         sharding=NamedSharding(mesh, P()))
w = jax.ShapeDtypeStruct((1024, 1024), jnp.float32,
                         sharding=NamedSharding(mesh, P(None, "model")))
with activate_mesh(mesh):
    c = jax.jit(f).lower(x, w).compile()
r = analyze_hlo(c.as_text())
per_step = 128 * 1024 * 4
total = sum(v for v in r.coll_breakdown.values())
assert abs(total - 5 * per_step) / (5 * per_step) < 0.05, r.coll_breakdown
print("COLL OK", r.coll_breakdown)
"""
    out = run_subprocess(code, n_devices=8)
    assert "COLL OK" in out


def test_parse_module_structure():
    def f(x):
        return jnp.sum(x * 2)
    txt = _compile(f, jax.ShapeDtypeStruct((64,), jnp.float32)).as_text()
    comps, entry = parse_module(txt)
    assert entry and entry in comps
    assert any(op.opcode in ("multiply", "fusion", "reduce")
               for op in comps[entry].ops) or len(comps) > 1


def test_roofline_fraction_math():
    rl = roofline.Roofline(
        arch="x", shape="train_4k", mesh="16x16",
        flops=1e12, hbm_bytes=1e11, coll_bytes=1e9,
        coll_breakdown={}, per_device_hbm_peak=1e10,
        model_flops=2.56e14, n_chips=256)
    # terms
    assert abs(rl.t_compute - 1e12 / roofline.PEAK_FLOPS_BF16) < 1e-12
    assert abs(rl.t_memory - 1e11 / roofline.HBM_BW) < 1e-12
    assert rl.bottleneck == "memory"
    ideal = 2.56e14 / 256 / roofline.PEAK_FLOPS_BF16
    assert abs(rl.roofline_fraction - ideal / rl.t_bound) < 1e-9


# ---------------------------------------------------------------------------
# parser edge cases (synthetic HLO text: deterministic and independent of
# what this compiler version happens to emit)
# ---------------------------------------------------------------------------
_WHILE_HLO = """
HloModule synthetic_while

%body (p: (s32[], f32[128,128])) -> (s32[], f32[128,128]) {
  %p = (s32[], f32[128,128]) parameter(0)
  %iv = s32[] get-tuple-element((s32[], f32[128,128]) %p), index=0
  %one = s32[] constant(1)
  %ivn = s32[] add(s32[] %iv, s32[] %one)
  %x = f32[128,128] get-tuple-element((s32[], f32[128,128]) %p), index=1
  %y = f32[128,128] dot(f32[128,128] %x, f32[128,128] %x), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %t = (s32[], f32[128,128]) tuple(s32[] %ivn, f32[128,128] %y)
}

%cond (q: (s32[], f32[128,128])) -> pred[] {
  %q = (s32[], f32[128,128]) parameter(0)
  %qiv = s32[] get-tuple-element((s32[], f32[128,128]) %q), index=0
  %n = s32[] constant(7)
  ROOT %lt = pred[] compare(s32[] %qiv, s32[] %n), direction=LT
}

ENTRY %main (arg: f32[128,128]) -> f32[128,128] {
  %arg = f32[128,128] parameter(0)
  %zero = s32[] constant(0)
  %init = (s32[], f32[128,128]) tuple(s32[] %zero, f32[128,128] %arg)
  %w = (s32[], f32[128,128]) while((s32[], f32[128,128]) %init), condition=%cond, body=%body{ANNOT}
  ROOT %out = f32[128,128] get-tuple-element((s32[], f32[128,128]) %w), index=1
}
"""


def test_trip_count_condition_fallback():
    # no backend_config: the condition's compare-against-constant(7) is
    # the only trip-count evidence
    r = analyze_hlo(_WHILE_HLO.replace("{ANNOT}", ""))
    assert r.n_while == 1 and r.max_trip == 7
    # dot + the s32 add (body) + the compare (cond), each executed x7
    assert r.flops == 7 * (2 * 128 ** 3 + 1 + 1)


def test_trip_count_known_annotation_wins():
    annot = (', backend_config={"known_trip_count":{"n":"12"}}')
    r = analyze_hlo(_WHILE_HLO.replace("{ANNOT}", annot))
    assert r.max_trip == 12                 # annotation beats the fallback 7
    assert r.flops == 12 * (2 * 128 ** 3 + 1 + 1)


_ZERO_HLO = """
HloModule synthetic_zero

ENTRY %main (a: f32[0,128], b: f32[128,64]) -> f32[0,64] {
  %a = f32[0,128] parameter(0)
  %b = f32[128,64] parameter(1)
  %d = f32[0,64] dot(f32[0,128] %a, f32[128,64] %b), lhs_contracting_dims={1}, rhs_contracting_dims={0}
  ROOT %r = f32[0,64] tanh(f32[0,64] %d)
}
"""


def test_zero_sized_operands():
    # a zero-element operand (empty expert / degenerate shard) must not
    # crash or contribute flops; only the nonzero operand costs bytes
    r = analyze_hlo(_ZERO_HLO)
    assert r.flops == 0.0
    assert r.bytes == 128 * 64 * 4          # %b read by the dot; rest is 0


_NESTED_FUSION_HLO = """
HloModule synthetic_nested_fusion

%inner (p0: f32[128]) -> f32[128] {
  %p0 = f32[128] parameter(0)
  ROOT %t = f32[128] tanh(f32[128] %p0)
}

%outer (q0: f32[128]) -> f32[128] {
  %q0 = f32[128] parameter(0)
  %m = f32[128] multiply(f32[128] %q0, f32[128] %q0)
  ROOT %f = f32[128] fusion(f32[128] %m), kind=kLoop, calls=%inner
}

ENTRY %main (a: f32[128]) -> f32[128] {
  %a = f32[128] parameter(0)
  ROOT %g = f32[128] fusion(f32[128] %a), kind=kLoop, calls=%outer
}
"""


def test_nested_fusion_flops_once_bytes_at_boundary():
    # ops inside (transitively) fused bodies cost flops exactly once, and
    # HBM bytes accrue only at the outermost fusion's operands/results
    r = analyze_hlo(_NESTED_FUSION_HLO)
    assert r.flops == 256.0                 # multiply(128) + tanh(128)
    assert r.bytes == 2 * 128 * 4           # %a in, %g out -- nothing inner


def test_no_entry_raises():
    with pytest.raises(ValueError, match="ENTRY"):
        analyze_hlo("%orphan (p: f32[4]) -> f32[4] {\n"
                    "  ROOT %p = f32[4] parameter(0)\n}\n")
