"""Loop-aware HLO analyzer: the roofline's source of truth."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.analysis.hlo import analyze_hlo, parse_module
from repro.analysis import roofline


def _compile(fn, *specs):
    return jax.jit(fn).lower(*specs).compile()


def test_scan_flops_scaled_by_trip_count():
    def scan10(x, w):
        def f(c, _):
            return jnp.tanh(c @ w), None
        y, _ = jax.lax.scan(f, x, None, length=10)
        return y

    def unrolled10(x, w):
        for _ in range(10):
            x = jnp.tanh(x @ w)
        return x

    x = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    w = jax.ShapeDtypeStruct((256, 256), jnp.float32)
    cs = _compile(scan10, x, w)
    cu = _compile(unrolled10, x, w)
    rs, ru = analyze_hlo(cs.as_text()), analyze_hlo(cu.as_text())
    analytic_dots = 10 * 2 * 256 ** 3

    # XLA's builtin undercounts the scan ~10x -- the bug we fix:
    ca = cs.cost_analysis()
    if isinstance(ca, list):      # jax < 0.6 returns one dict per device
        ca = ca[0]
    assert ca["flops"] < 0.2 * analytic_dots
    # our analyzer agrees with both the unrolled version and the math:
    assert abs(rs.flops - ru.flops) / ru.flops < 0.01
    assert abs(rs.flops - analytic_dots) / analytic_dots < 0.01
    assert rs.n_while == 1 and rs.max_trip == 10


def test_nested_scan_multipliers():
    def nested(x, w):
        def outer(c, _):
            def inner(ci, _):
                return ci @ w, None
            ci, _ = jax.lax.scan(inner, c, None, length=4)
            return ci, None
        y, _ = jax.lax.scan(outer, x, None, length=3)
        return y

    x = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    w = jax.ShapeDtypeStruct((128, 128), jnp.float32)
    r = analyze_hlo(_compile(nested, x, w).as_text())
    analytic = 3 * 4 * 2 * 128 ** 3
    assert abs(r.flops - analytic) / analytic < 0.02


def test_dot_general_batch_dims():
    def f(a, b):
        return jnp.einsum("bij,bjk->bik", a, b)

    a = jax.ShapeDtypeStruct((4, 64, 32), jnp.float32)
    b = jax.ShapeDtypeStruct((4, 32, 16), jnp.float32)
    r = analyze_hlo(_compile(f, a, b).as_text())
    analytic = 2 * 4 * 64 * 32 * 16
    assert abs(r.flops - analytic) / analytic < 0.01


def test_bytes_sane():
    def f(a, b):
        return a @ b

    a = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    b = jax.ShapeDtypeStruct((512, 512), jnp.float32)
    r = analyze_hlo(_compile(f, a, b).as_text())
    io_bytes = 3 * 512 * 512 * 4
    assert io_bytes <= r.bytes <= 2 * io_bytes


def test_collectives_multiplied(run_subprocess):
    code = """
import jax, jax.numpy as jnp
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.analysis.hlo import analyze_hlo
from repro.launch.mesh import activate_mesh, make_mesh

mesh = make_mesh((8,), ("model",))
def f(x, w):
    def body(c, _):
        y = jax.lax.with_sharding_constraint(
            c @ w, NamedSharding(mesh, P(None, "model")))
        y = jax.lax.with_sharding_constraint(
            y @ w.T, NamedSharding(mesh, P()))
        return y, None
    y, _ = jax.lax.scan(body, x, None, length=5)
    return y

x = jax.ShapeDtypeStruct((128, 1024), jnp.float32,
                         sharding=NamedSharding(mesh, P()))
w = jax.ShapeDtypeStruct((1024, 1024), jnp.float32,
                         sharding=NamedSharding(mesh, P(None, "model")))
with activate_mesh(mesh):
    c = jax.jit(f).lower(x, w).compile()
r = analyze_hlo(c.as_text())
per_step = 128 * 1024 * 4
total = sum(v for v in r.coll_breakdown.values())
assert abs(total - 5 * per_step) / (5 * per_step) < 0.05, r.coll_breakdown
print("COLL OK", r.coll_breakdown)
"""
    out = run_subprocess(code, n_devices=8)
    assert "COLL OK" in out


def test_parse_module_structure():
    def f(x):
        return jnp.sum(x * 2)
    txt = _compile(f, jax.ShapeDtypeStruct((64,), jnp.float32)).as_text()
    comps, entry = parse_module(txt)
    assert entry and entry in comps
    assert any(op.opcode in ("multiply", "fusion", "reduce")
               for op in comps[entry].ops) or len(comps) > 1


def test_roofline_fraction_math():
    rl = roofline.Roofline(
        arch="x", shape="train_4k", mesh="16x16",
        flops=1e12, hbm_bytes=1e11, coll_bytes=1e9,
        coll_breakdown={}, per_device_hbm_peak=1e10,
        model_flops=2.56e14, n_chips=256)
    # terms
    assert abs(rl.t_compute - 1e12 / roofline.PEAK_FLOPS_BF16) < 1e-12
    assert abs(rl.t_memory - 1e11 / roofline.HBM_BW) < 1e-12
    assert rl.bottleneck == "memory"
    ideal = 2.56e14 / 256 / roofline.PEAK_FLOPS_BF16
    assert abs(rl.roofline_fraction - ideal / rl.t_bound) < 1e-9
