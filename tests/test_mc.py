"""Control-plane model checker suite (src/repro/analysis/mc/).

Four layers:

* gate -- the shipped bounded configurations exhaust (every reachable
  interleaving expanded, memoized, terminating) with ZERO violations;
  this is the property CI enforces with an empty baseline.
* oracle self-tests -- planted bugs (``sabotage=`` configs) must be
  FOUND with the right GL8xx codes: a checker that cannot see a
  deliberate refcount leak / token rewind / arena wedge proves nothing
  by reporting clean.
* counterexample machinery -- greedy minimization, deterministic
  replay (identical violating state hash across re-executions), spec
  round-trip, exported pytest/fault-script artifacts.
* decision equivalence -- the NullEngine (fabricated compute) makes the
  same scheduling/allocation decisions as the real ServingEngine on
  identical op sequences, so checking the null engine checks the one
  that serves.
"""

import numpy as np
import pytest

import jax.numpy as jnp

from repro.analysis.mc import explore as mcx
from repro.analysis.mc.canon import canonical_state, state_tuple
from repro.analysis.mc.harness import (ALL_CONFIGS, CONFIGS,
                                       SELFTEST_CONFIGS, LogicalClock,
                                       MCConfig, NullEngine, build_engine)
from repro.models import transformer as tf
from repro.serving.engine import ServingEngine


# ---------------------------------------------------------------------------
# the gate: shipped configs exhaust with zero violations
# ---------------------------------------------------------------------------
def test_acceptance_config_exhausts_clean():
    """The ISSUE's acceptance bar: a 3-slot/12-page/3-request config is
    fully exhausted -- reported state count, memoization hits, proper
    termination -- with no GL8xx findings."""
    cfg = CONFIGS["core-3s12p"]
    assert (cfg.slots, cfg.pages, len(cfg.prompts)) == (3, 12, 3)
    res = mcx.explore(cfg)
    assert res.complete, "state/depth budget must not cap the core config"
    assert res.violations == []
    assert res.states >= 100            # non-trivial interleaving space
    assert res.memo_hits > 0            # canonicalization actually merges
    assert res.terminal_states > 0      # every path can drain
    assert res.transitions >= res.states - 1


@pytest.mark.parametrize("name", sorted(CONFIGS))
def test_shipped_config_clean(name):
    res = mcx.explore(CONFIGS[name])
    assert res.complete and res.violations == []


def test_capped_run_skips_graph_checks():
    """An exploration that hits the state budget must mark itself
    incomplete and NOT emit GL804/GL806 (they are only sound over the
    complete graph)."""
    res = mcx.explore(CONFIGS["core-3s12p"], max_states=5)
    assert not res.complete
    assert all(v.code not in ("GL804", "GL806") for v in res.violations)


# ---------------------------------------------------------------------------
# oracle self-tests: planted bugs must be found
# ---------------------------------------------------------------------------
def _codes(res):
    return {v.code for v in res.violations}


def test_selftest_defrag_leak_found():
    res = mcx.explore(SELFTEST_CONFIGS["sabotage-defrag-leak"])
    assert {"GL801", "GL803"} <= _codes(res)


def test_selftest_rewind_found():
    res = mcx.explore(SELFTEST_CONFIGS["sabotage-rewind"])
    assert "GL802" in _codes(res)


def test_selftest_wedge_found():
    """The lost-request + page-hold plant breaks both graph properties:
    states exist from which neither admission capacity nor a drained
    workload is ever reachable."""
    res = mcx.explore(SELFTEST_CONFIGS["sabotage-wedge"])
    assert res.complete                   # graph checks need exhaustion
    assert {"GL804", "GL806"} <= _codes(res)


# ---------------------------------------------------------------------------
# counterexample machinery
# ---------------------------------------------------------------------------
def _first(res, code):
    return next(v for v in res.violations if v.code == code)


def test_minimize_defrag_leak_to_three_actions():
    cfg = SELFTEST_CONFIGS["sabotage-defrag-leak"]
    res = mcx.explore(cfg)
    v = mcx.minimize(cfg, _first(res, "GL801"))
    assert v.trace == ("submit", "prefill", "defrag")
    # each violation keeps ITS OWN message even when one transition
    # breaks several invariants at once
    v3 = mcx.minimize(cfg, _first(res, "GL803"))
    assert v3.code == "GL803" and "ref_multiset" in v3.message
    assert "allocator invariant" in v.message


def test_replay_deterministic_state_hash():
    """Acceptance bar: re-running an exported counterexample reproduces
    the identical violating state hash."""
    cfg = SELFTEST_CONFIGS["sabotage-rewind"]
    res = mcx.explore(cfg)
    v = mcx.minimize(cfg, _first(res, "GL802"))
    r1 = mcx.replay(cfg, v.trace)
    r2 = mcx.replay(cfg, v.trace)
    assert r1.valid and r2.valid
    assert r1.violation.code == "GL802"
    assert r1.state_hash == r2.state_hash == v.state_hash


def test_replay_rejects_disabled_action():
    r = mcx.replay(CONFIGS["core-3s12p"], ("decode",))   # nothing running
    assert not r.valid and r.violation is None


def test_replay_clean_trace():
    r = mcx.replay(CONFIGS["core-3s12p"], ("submit", "prefill"))
    assert r.valid and r.violation is None and r.executed == 2


def test_spec_roundtrip():
    spec = mcx.format_spec("core-3s12p", ("submit", "prefill", "decode"))
    cfg, trace = mcx.parse_spec(spec)
    assert cfg is ALL_CONFIGS["core-3s12p"]
    assert trace == ("submit", "prefill", "decode")
    with pytest.raises(ValueError):
        mcx.parse_spec("mc:v1;config=no-such;trace=a")
    with pytest.raises(ValueError):
        mcx.parse_spec("not-a-spec")


def test_export_artifacts(tmp_path):
    cfg = SELFTEST_CONFIGS["sabotage-defrag-leak"]
    res = mcx.explore(cfg)
    v = mcx.minimize(cfg, _first(res, "GL801"))
    src = mcx.export_pytest(v)
    p = tmp_path / "test_ce.py"
    p.write_text(src)
    # the generated regression is itself a collectible, passing test
    assert "def test_mc_counterexample_" in src
    ret = pytest.main(["-x", "-q", str(p)])
    assert ret == 0
    sh = mcx.export_fault_script(v)
    assert sh.startswith("#!/bin/sh")
    assert mcx.format_spec(v.config, v.trace) in sh


def test_fault_script_carries_armed_plan():
    v = mcx.Violation("GL807", "boom", ("submit", "fault:nan", "prefill"),
                      "exception", "faults-2s8p")
    sh = mcx.export_fault_script(v)
    assert "GEMMINI_FAULTS" in sh and "nan@" in sh


# ---------------------------------------------------------------------------
# canonicalization
# ---------------------------------------------------------------------------
def test_canonical_hash_is_pure_and_stable():
    cfg = CONFIGS["core-3s12p"]
    e1, e2 = build_engine(cfg), build_engine(cfg)
    assert canonical_state(e1) == canonical_state(e2)
    h0 = canonical_state(e1)
    assert state_tuple(e1) == state_tuple(e1)   # reading does not mutate
    assert canonical_state(e1) == h0


def test_canonical_hash_bounds_preempt_cycles():
    """n_preempted clamps to {0,1} and n_chunks (cumulative telemetry) is
    excluded, so preempt/re-admit churn cannot mint unbounded fresh
    states -- the property that makes exploration terminate. Decision
    inputs (n_generated) must still distinguish states."""
    import copy
    from repro.analysis.mc.actions import apply_action
    cfg = MCConfig(name="cycle", slots=1, pages=8, page_size=4,
                   max_context=16, prompts=((1, 2, 3),), max_new=(4,),
                   prefill_chunk=4, allow_defrag=False)
    eng = build_engine(cfg)
    apply_action(eng, "submit")
    apply_action(eng, "prefill")
    apply_action(eng, "preempt")
    other = copy.deepcopy(eng)
    req = other.requests[0]
    req.n_chunks += 17                       # telemetry: not canonical
    req.n_preempted = 9                      # clamps to the same bucket
    assert canonical_state(other) == canonical_state(eng)
    req.generated.append(0)                  # a decision input IS canonical
    assert canonical_state(other) != canonical_state(eng)


# ---------------------------------------------------------------------------
# decision equivalence: NullEngine vs the real ServingEngine
# ---------------------------------------------------------------------------
_TINY = tf.ModelConfig(name="tiny-mc", family="dense", n_layers=2,
                       d_model=32, vocab=64, n_heads=2, n_kv_heads=1,
                       head_dim=16, d_ff=64, dtype=jnp.float32)

_EQ_PROMPTS = ((1, 2, 3, 4, 5), (6, 7, 8), (9, 10, 11, 12, 13, 14))
_EQ_MAX_NEW = (3, 2, 2)


def _decision_view(eng):
    """Everything the control plane decided, nothing the compute did:
    queue order, running map, per-request lifecycle counters, allocator
    accounting."""
    return (
        tuple(r.rid for r in eng.sched.queue),
        tuple(sorted((slot, r.rid, r.cache_len, r.prefill_pos,
                      r.n_generated, r.state)
                     for slot, r in eng.sched.running.items())),
        tuple(sorted((r.rid, r.state, r.n_generated, len(r.generated),
                      bool(r.truncated), r.n_preempted)
                     for r in eng.requests)),
        eng.alloc.used_pages,
        eng.alloc.free_pages,
    )


def _apply_ops(eng, ops):
    trail = []
    for op in ops:
        if op == "submit":
            i = len(eng.requests)
            eng.submit(np.asarray(_EQ_PROMPTS[i], np.int32),
                       _EQ_MAX_NEW[i], eos_id=-1)
        elif op == "step":
            eng.step()
        elif op == "preempt":
            if eng.sched.running:
                eng.sched.preempt(eng.sched._eviction_victim())
        elif op == "defrag":
            eng.defrag()
        trail.append(_decision_view(eng))
    # drain like run(): every request must reach a terminal state
    it = 0
    while eng.sched.has_work:
        eng.step()
        trail.append(_decision_view(eng))
        it += 1
        assert it < 200, "drain did not terminate"
    return trail


@pytest.mark.parametrize("seed", [0, 1, 2])
def test_null_engine_decision_equivalent_to_serving_engine(seed):
    """PR-9-style op sequences (submit/step/preempt/defrag + drain) drive
    the real interpret-backend ServingEngine and the tensor-free
    NullEngine through identical decision trails: same admissions, same
    chunk/decode progress, same preemption victims, same page
    accounting at every op boundary."""
    rng = np.random.default_rng(seed)
    ops = ["submit", "step", "submit", "step", "submit"]
    for _ in range(8):
        ops.append(rng.choice(["step", "step", "preempt", "defrag"]))

    real = ServingEngine(
        _TINY, max_slots=2, max_context=32, page_size=8, n_pages=8,
        prefill_chunk=8, prefill_token_budget=8, backend="interpret",
        seed=0, clock=LogicalClock())
    null = NullEngine(MCConfig(
        name="equiv", slots=2, pages=8, page_size=8, max_context=32,
        prompts=_EQ_PROMPTS, max_new=_EQ_MAX_NEW, prefill_chunk=8,
        prefill_token_budget=8))

    t_real = _apply_ops(real, ops)
    t_null = _apply_ops(null, ops)
    assert t_real == t_null


def test_null_engine_assert_invariants_off_by_default():
    """The checker supplies its own oracle; the engine-level knob must
    stay off so GL801 attribution (which action broke it) is precise."""
    eng = build_engine(CONFIGS["core-3s12p"])
    assert eng.assert_invariants is False
