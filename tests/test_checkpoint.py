"""Checkpoint: bit-exact roundtrip, async save, GC, elastic reshard."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.checkpoint import (CheckpointManager, latest_step,
                              restore_checkpoint, save_checkpoint)


def _tree(rng):
    return {
        "w": jnp.asarray(rng.standard_normal((8, 16)), jnp.float32),
        "b16": jnp.asarray(rng.standard_normal((4, 4)), jnp.bfloat16),
        "i": jnp.asarray(rng.integers(0, 100, (5,)), jnp.int32),
        "nested": {"scale": jnp.asarray(1.5, jnp.float32)},
    }


def _shardings(tree):
    dev = jax.devices()[0]
    s = jax.sharding.SingleDeviceSharding(dev)
    return jax.tree.map(lambda _: s, tree)


def test_roundtrip_bitexact(tmp_path, rng):
    tree = _tree(rng)
    save_checkpoint(str(tmp_path), 7, tree)
    assert latest_step(str(tmp_path)) == 7
    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    restored = restore_checkpoint(str(tmp_path), 7, target,
                                  _shardings(tree))
    for a, b in zip(jax.tree.leaves(tree), jax.tree.leaves(restored)):
        assert a.dtype == b.dtype
        assert bool(jnp.all(a == b)), (a, b)


def test_uncommitted_checkpoint_ignored(tmp_path, rng):
    tree = _tree(rng)
    save_checkpoint(str(tmp_path), 3, tree)
    # fake a torn save at a later step
    os.makedirs(tmp_path / "step_00000009")
    assert latest_step(str(tmp_path)) == 3


def test_manager_async_and_gc(tmp_path, rng):
    tree = _tree(rng)
    mgr = CheckpointManager(str(tmp_path), keep=2)
    for s in (1, 2, 3, 4):
        mgr.save_async(s, tree)
    mgr.wait()
    mgr.save(5, tree)
    steps = sorted(int(n.split("_")[1]) for n in os.listdir(tmp_path)
                   if n.startswith("step_"))
    assert steps == [4, 5]

    target = jax.tree.map(
        lambda x: jax.ShapeDtypeStruct(x.shape, x.dtype), tree)
    step, restored = mgr.restore_latest(target, _shardings(tree))
    assert step == 5
    assert bool(jnp.all(restored["w"] == tree["w"]))


def test_elastic_reshard_restore(tmp_path, run_subprocess):
    """Save sharded on mesh (4, 2), restore onto mesh (2, 4) -- the elastic
    pod-loss path (different layout, same global arrays)."""
    code = f"""
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import PartitionSpec as P, NamedSharding
from repro.launch.mesh import activate_mesh, make_mesh
from repro.checkpoint import save_checkpoint, restore_checkpoint

mesh1 = make_mesh((4, 2), ("data", "model"))
mesh2 = make_mesh((2, 4), ("data", "model"))
x = jnp.arange(64 * 32, dtype=jnp.float32).reshape(64, 32)
s1 = NamedSharding(mesh1, P("data", "model"))
s2 = NamedSharding(mesh2, P("model", "data"))
tree = {{"x": jax.device_put(x, s1),
         "y": jax.device_put(x.astype(jnp.bfloat16), s1)}}
save_checkpoint(r"{tmp_path}", 1, tree)
target = {{"x": jax.ShapeDtypeStruct((64, 32), jnp.float32),
           "y": jax.ShapeDtypeStruct((64, 32), jnp.bfloat16)}}
restored = restore_checkpoint(r"{tmp_path}", 1, target,
                              {{"x": s2, "y": s2}})
assert restored["x"].sharding == s2
np.testing.assert_array_equal(np.asarray(restored["x"]), np.asarray(x))
np.testing.assert_array_equal(
    np.asarray(restored["y"], np.float32),
    np.asarray(x.astype(jnp.bfloat16), np.float32))
print("ELASTIC OK")
"""
    out = run_subprocess(code, n_devices=8)
    assert "ELASTIC OK" in out
