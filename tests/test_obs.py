"""Observability stack: span tracer, metrics registry, kernel profiler.

The invariants under test (ISSUE 8: full-stack observability):

* **Off by default, bit-exact when off.** A traced engine produces the
  same tokens as an untraced one; every emission site is a None check.
* **Bounded.** The event ring never grows past its capacity; overflow is
  counted, not silently eaten.
* **Well-formed.** Every exported trace validates against the Chrome
  trace event schema (the CI gate `python -m repro.obs --check` runs).
* **Complete.** With tracing on, every request-lifecycle stage —
  including forced preemption and forced fault fallback — lands as an
  event, and the allocator/engine/fault tracks populate.
* **Honest math.** Percentiles over empty populations are None (never a
  fabricated 0.0), and the profiler's contract-derived FLOPs are exact
  for known shapes across all kernel families.
"""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.core.config import GemminiConfig
from repro.core.context import ExecutionContext
from repro.models import transformer as tf
from repro.obs import profile as oprofile
from repro.obs import trace as otrace
from repro.obs.metrics import MetricsRegistry
from repro.obs.profile import Profiler
from repro.obs.trace import Tracer, req_tid, validate_chrome
from repro.serving.engine import ServingEngine
from repro.serving.scheduler import _pct

_TINY = tf.ModelConfig(name="tiny-serve", family="dense", n_layers=2,
                       d_model=32, vocab=64, n_heads=2, n_kv_heads=1,
                       head_dim=16, d_ff=64, dtype=jnp.float32)


@pytest.fixture(autouse=True)
def _no_global_sinks():
    """Tests must not leak a process-global tracer/profiler into each
    other (or into the rest of the suite)."""
    yield
    otrace.deactivate()
    oprofile.deactivate()


def _names(events, cat=None):
    return [e["name"] for e in events
            if cat is None or e.get("cat") == cat]


# ---------------------------------------------------------------------------
# tracer core
# ---------------------------------------------------------------------------
def test_ring_bounds_and_counts_drops():
    tr = Tracer(capacity=4)
    for i in range(6):
        tr.instant(f"e{i}")
    assert len(tr.events) == 4
    assert tr.dropped == 2
    # oldest evicted first
    assert _names(tr.events) == ["e2", "e3", "e4", "e5"]


def test_injected_clock_deterministic_timestamps():
    t = [100.0]
    tr = Tracer(capacity=16, clock=lambda: t[0])
    t[0] = 100.5
    tr.instant("a")
    t[0] = 101.0
    tr.complete("s", 100.25, 100.75, cat="engine")
    a, s = tr.events
    assert a["ts"] == pytest.approx(0.5e6)
    assert s["ts"] == pytest.approx(0.25e6) and s["dur"] == pytest.approx(0.5e6)


def test_chrome_export_schema_valid(tmp_path):
    tr = Tracer(capacity=64)
    tr.instant("i", cat="alloc", tid=otrace.TID_ALLOC, slot=1)
    with tr.span("work", cat="engine"):
        pass
    tr.counter("arena_pages", used=3, free=5)
    payload = tr.chrome()
    assert validate_chrome(payload) == []
    path = tmp_path / "t.json"
    tr.export_chrome(str(path))
    assert validate_chrome(json.loads(path.read_text())) == []
    # and the JSONL round-trip yields the same events
    jl = tmp_path / "t.jsonl"
    tr.export_jsonl(str(jl))
    assert otrace.load(str(jl)) == list(tr.events)


def test_validator_rejects_malformed_events():
    bad = {"traceEvents": [
        {"name": "x", "ph": "X", "ts": 0, "pid": 0, "tid": 0},   # no dur
        {"name": "y", "ph": "??", "ts": 0, "pid": 0, "tid": 0},  # bad phase
        {"ph": "i", "ts": 0, "pid": 0, "tid": 0},                # no name
    ]}
    errs = validate_chrome(bad)
    assert len(errs) == 3
    assert validate_chrome("nope") and validate_chrome({"foo": 1})


# ---------------------------------------------------------------------------
# metrics registry
# ---------------------------------------------------------------------------
def test_counter_label_aggregation():
    m = MetricsRegistry()
    m.counter("retries", site="decode").inc()
    m.counter("retries", site="decode").inc()
    m.counter("retries", site="prefill").inc()
    assert m.value("retries") == 3.0
    assert m.counters_flat() == {"retries": 3.0}
    snap = m.snapshot()
    assert snap["counters"]["retries{site=decode}"] == 2.0


def test_gauge_peaks_and_series():
    m = MetricsRegistry(gauge_series=8)
    for t, v in enumerate((2, 7, 3)):
        m.gauge("arena_used_pages").set(v, t=float(t))
    assert m.gauge_peak("arena_used_pages") == 7
    assert m.gauge_peaks() == {"arena_used_pages_peak": 7}
    assert list(m.gauge("arena_used_pages").series) == [
        (0.0, 2), (1.0, 7), (2.0, 3)]


def test_histogram_empty_percentile_is_none():
    m = MetricsRegistry()
    h = m.histogram("latency_s")
    assert h.percentile(50) is None and h.mean is None
    for v in (1.0, 2.0, 3.0, 4.0):
        h.observe(v)
    assert h.percentile(50) == pytest.approx(2.5)
    assert h.percentile(100) == 4.0 and h.mean == 2.5


def test_summarize_percentiles_none_for_empty_population():
    assert _pct([], 50) is None
    assert _pct([3.0], 99) == 3.0
    # engine-level: a run with zero requests must report null percentiles,
    # not fabricated 0.0s (the old `or [0.0]` bug)
    eng = ServingEngine(_TINY, max_slots=1, max_context=32, page_size=8,
                        n_pages=4, temperature=0.0, seed=0)
    s = eng.run()["summary"]
    assert s["requests"] == 0
    for k in ("p50_latency_s", "p99_latency_s", "p50_ttft_s",
              "p99_ttft_s", "p50_itl_s", "p95_itl_s"):
        assert s[k] is None, k


# ---------------------------------------------------------------------------
# engine integration: bit-exactness + lifecycle completeness
# ---------------------------------------------------------------------------
def _engine(trace=None, **kw):
    kw.setdefault("max_slots", 2)
    kw.setdefault("max_context", 32)
    kw.setdefault("page_size", 8)
    kw.setdefault("n_pages", 8)
    return ServingEngine(_TINY, temperature=0.0, seed=0, trace=trace, **kw)


def _run_tokens(eng, rng, lens=(5, 9), gen=4):
    for n in lens:
        eng.submit(rng.integers(0, 64, (n,), dtype=np.int32), gen)
    rep = eng.run()
    return [np.asarray(r["tokens"]).ravel() for r in rep["requests"]], rep


def test_traced_engine_bit_identical_tokens():
    params = tf.init_params(jax.random.PRNGKey(3), _TINY)
    plain, _ = _run_tokens(_engine(params=params),
                           np.random.default_rng(0))
    traced_eng = _engine(trace=True, params=params)
    traced, _ = _run_tokens(traced_eng, np.random.default_rng(0))
    for a, b in zip(plain, traced):
        np.testing.assert_array_equal(a, b)
    assert traced_eng.tracer is not None and len(traced_eng.tracer.events)


def test_lifecycle_events_under_forced_preemption():
    # Starved arena (the test_engine_correct_under_eviction geometry):
    # preemption-by-eviction must fire, and every stage must land.
    rng = np.random.default_rng(0)
    eng = _engine(trace=True, n_pages=4)
    for n, g in zip((7, 9, 6), (10, 9, 8)):
        eng.submit(rng.integers(0, 64, (n,), dtype=np.int32), g)
    rep = eng.run()
    assert rep["summary"]["preemptions"] > 0
    evs = list(eng.tracer.events)
    req_names = set(_names(evs, cat="request"))
    assert {"submitted", "queued", "preempt", "token", "decode",
            "finished"} <= req_names
    assert any(n.startswith("prefill") for n in req_names)
    assert {"alloc", "evict"} <= set(_names(evs, cat="alloc"))
    assert "step" in _names(evs, cat="engine")
    assert "arena_pages" in _names(evs, cat="metrics")
    # one lane per request, and every request's lane has a terminal event
    for rid in range(3):
        lane = [e for e in evs if e["tid"] == req_tid(rid)]
        assert "finished" in [e["name"] for e in lane]
    # registry agrees with the trace
    assert eng.metrics.value("preemptions") == rep["summary"]["preemptions"]
    assert validate_chrome(eng.tracer.chrome()) == []


def test_lifecycle_events_under_forced_fallback():
    # A NaN-poisoned decode forces the xla_twin fallback; the fault firing
    # and the fallback must both land on their tracks.
    rng = np.random.default_rng(0)
    eng = _engine(trace=True, backend="interpret", prefill_chunk=8,
                  faults="seed=1;nan@decode:max=1")
    for n in (5, 11):
        eng.submit(rng.integers(0, 64, (n,), dtype=np.int32), 4)
    rep = eng.run()
    assert rep["summary"]["fallbacks"] == 1
    evs = list(eng.tracer.events)
    assert "fallback" in _names(evs, cat="engine")
    assert "fault:nan" in _names(evs, cat="fault")
    assert eng.counters["fallbacks"] == 1          # compat view intact


def test_hang_report_dumps_diagnostics():
    rng = np.random.default_rng(0)
    eng = _engine(trace=True)
    eng.submit(rng.integers(0, 64, (5,), dtype=np.int32), 4)
    eng.max_run_iters = 1
    with pytest.raises(RuntimeError) as exc:
        eng.run()
    msg = str(exc.value)
    assert "did not converge" in msg
    for needle in ("queue", "arena", "counters", "slot"):
        assert needle in msg, needle


# ---------------------------------------------------------------------------
# kernel profiler
# ---------------------------------------------------------------------------
_CFG = GemminiConfig(input_dtype="bf16", acc_dtype="fp32",
                     output_dtype="bf16")


def _profiled_ctx():
    prof = Profiler()
    oprofile.install(prof)
    ctx = ExecutionContext(cfg=_CFG, backend="interpret", tune_mode="off")
    return prof, ctx


def test_profiler_covers_all_kernel_families():
    """One eager dispatch per kernel family on the interpret backend:
    every bucket must carry a contract join and a utilization verdict."""
    prof, ctx = _profiled_ctx()
    f32, i32 = jnp.float32, jnp.int32
    # gemm + matmul (gemm engine)
    ctx.gemm(jnp.ones((16, 32), jnp.bfloat16), jnp.ones((32, 8), jnp.bfloat16))
    ctx.matmul(jnp.ones((2, 8, 32), jnp.bfloat16),
               jnp.ones((32, 8), jnp.bfloat16))
    # conv2d
    ctx.conv2d(jnp.ones((1, 8, 8, 8), jnp.bfloat16),
               jnp.ones((3, 3, 8, 8), jnp.bfloat16))
    # flash attention
    q = jnp.ones((1, 16, 2, 16), f32)
    k = jnp.ones((1, 16, 1, 16), f32)
    ctx.flash_attention(q, k, k)
    # paged decode + paged prefill
    pool = jnp.zeros((1, 5, 8, 16), f32)
    ctx.paged_attention(jnp.ones((2, 1, 2, 16), f32), pool, pool,
                        jnp.zeros((2, 2), i32), jnp.ones((2,), i32))
    ctx.paged_prefill_attention(jnp.ones((1, 8, 2, 16), f32), pool, pool,
                                jnp.zeros((4,), i32), 0)
    # ssd (mamba-2 mixer)
    x = jnp.ones((1, 32, 2, 16), f32)
    ctx.ssd(x, jnp.ones((1, 32, 2), f32), -jnp.ones((2,), f32),
            jnp.ones((1, 32, 1, 8), f32), jnp.ones((1, 32, 1, 8), f32),
            chunk=16)

    rows = {r["op"]: r for r in prof.snapshot()}
    want = {"gemm", "matmul", "conv2d", "flash_attention",
            "paged_attention", "paged_prefill_attention", "ssd"}
    assert want <= set(rows)
    for op in want:
        r = rows[op]
        assert r["contract"], op
        assert r["flops"] > 0 and r["bytes"] > 0, op
        assert r["calls"] == 1 and r["min_s"] is not None, op
        assert r["compute_util"] is not None and r["compute_util"] >= 0, op
        assert r["bound"] in ("compute", "memory"), op
    # contract-derived FLOPs are exact for known shapes
    assert rows["gemm"]["flops"] == 2.0 * 16 * 8 * 32
    assert rows["matmul"]["flops"] == 2.0 * 16 * 8 * 32
    assert rows["flash_attention"]["flops"] == 4.0 * 1 * 2 * 16 * 16 * 16
    assert "gemm" in prof.report()


def test_profiled_dispatch_values_unchanged():
    a = jnp.asarray(np.random.default_rng(0).standard_normal((16, 32)),
                    jnp.bfloat16)
    b = jnp.asarray(np.random.default_rng(1).standard_normal((32, 8)),
                    jnp.bfloat16)
    plain_ctx = ExecutionContext(cfg=_CFG, backend="xla", tune_mode="off")
    want = np.asarray(plain_ctx.gemm(a, b))
    prof = Profiler()
    oprofile.install(prof)
    got = np.asarray(
        ExecutionContext(cfg=_CFG, backend="xla", tune_mode="off").gemm(a, b))
    np.testing.assert_array_equal(want, got)
    assert next(iter(prof.buckets.values())).calls == 1


def test_profiler_emits_kernel_spans_to_tracer():
    tr = Tracer(capacity=32)
    prof = Profiler(tracer=tr)
    oprofile.install(prof)
    ctx = ExecutionContext(cfg=_CFG, backend="xla", tune_mode="off")
    ctx.gemm(jnp.ones((8, 8), jnp.bfloat16), jnp.ones((8, 8), jnp.bfloat16))
    spans = [e for e in tr.events if e.get("cat") == "kernel"]
    assert len(spans) == 1 and spans[0]["name"] == "gemm"
    assert spans[0]["args"]["flops"] == 2.0 * 8 * 8 * 8


# ---------------------------------------------------------------------------
# CLI (python -m repro.obs)
# ---------------------------------------------------------------------------
def test_cli_check_exit_codes(tmp_path, capsys):
    from repro.obs.__main__ import main
    tr = Tracer(capacity=32)
    tr.instant("submitted", cat="request", tid=req_tid(0))
    tr.complete("step", tr.clock() - 1e-3, cat="engine")
    good = tmp_path / "good.json"
    tr.export_chrome(str(good))
    assert main([str(good), "--check"]) == 0
    assert "OK" in capsys.readouterr().out

    bad = tmp_path / "bad.json"
    bad.write_text(json.dumps({"traceEvents": [
        {"name": "x", "ph": "X", "ts": 0, "pid": 0, "tid": 0}]}))
    assert main([str(bad), "--check"]) == 1
    assert "SCHEMA" in capsys.readouterr().err
    assert main([str(tmp_path / "missing.json")]) == 2


def test_cli_summary_renders(tmp_path, capsys):
    from repro.obs.__main__ import main
    rng = np.random.default_rng(0)
    eng = _engine(trace=True)
    _run_tokens(eng, rng)
    path = tmp_path / "t.json"
    eng.tracer.export_chrome(str(path))
    assert main([str(path)]) == 0
    out = capsys.readouterr().out
    assert "top spans" in out and "req 0" in out
