"""Generated GEMM engine: interpret=True kernel vs pure-jnp oracle.

Sweeps shapes, dtypes, dataflows, bias, shift, activation -- bit-exact for
the integer datapath, allclose for float paths.
"""

import jax.numpy as jnp
import numpy as np
import pytest
try:
    from hypothesis import given, settings, strategies as st
except ImportError:                       # container has no hypothesis
    from _hyp import given, settings, strategies as st

from repro.core.config import Activation, Dataflow, GemminiConfig
from repro.core.generator import elaborate
from repro.core.context import ExecutionContext
from repro.kernels import ref


def _ctx(cfg, backend="interpret"):
    return ExecutionContext(cfg=cfg, backend=backend)


def _ints(rng, shape, lo=-128, hi=128, dtype=jnp.int8):
    return jnp.asarray(rng.integers(lo, hi, shape), dtype)


def _floats(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


@pytest.mark.parametrize("df", [Dataflow.OS, Dataflow.WS])
@pytest.mark.parametrize("shape", [(128, 128, 128), (200, 136, 260),
                                   (1, 1000, 784), (384, 128, 512)])
@pytest.mark.parametrize("bias", [False, True])
def test_int8_gemm_bitexact(rng, df, shape, bias):
    m, n, k = shape
    cfg = GemminiConfig(dataflow=df)
    a = _ints(rng, (m, k))
    b = _ints(rng, (k, n))
    d = _ints(rng, (1, n), -1000, 1000, jnp.int32) if bias else None
    y = _ctx(cfg).gemm(a, b, d, shift=8, activation=Activation.RELU)
    yr = ref.gemm_ref(a, b, d, acc_dtype=jnp.int32, out_dtype=jnp.int8,
                      shift=8, activation=Activation.RELU)
    assert y.dtype == jnp.int8
    assert bool(jnp.all(y == yr))


@pytest.mark.parametrize("df", [Dataflow.OS, Dataflow.WS])
@pytest.mark.parametrize("in_dt,acc_dt,out_dt",
                         [("bf16", "fp32", "bf16"), ("fp32", "fp32", "fp32")])
def test_float_gemm_allclose(rng, df, in_dt, acc_dt, out_dt):
    cfg = GemminiConfig(dataflow=df, input_dtype=in_dt, acc_dtype=acc_dt,
                        output_dtype=out_dt)
    a = _floats(rng, (160, 96)).astype(cfg.input_jnp)
    b = _floats(rng, (96, 224)).astype(cfg.input_jnp)
    y = _ctx(cfg).gemm(a, b, None)
    yr = ref.gemm_ref(a, b, None, acc_dtype=cfg.acc_jnp,
                      out_dtype=cfg.output_jnp)
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=2e-2 if in_dt == "bf16" else 1e-5,
                               atol=1e-2 if in_dt == "bf16" else 1e-5)


@settings(max_examples=12, deadline=None)
@given(m=st.integers(1, 300), n=st.integers(1, 300), k=st.integers(1, 300),
       df=st.sampled_from([Dataflow.OS, Dataflow.WS]),
       shift=st.integers(0, 12))
def test_int8_gemm_property(m, n, k, df, shift):
    rng = np.random.default_rng(m * 7 + n * 3 + k)
    cfg = GemminiConfig(dataflow=df)
    a = _ints(rng, (m, k))
    b = _ints(rng, (k, n))
    y = _ctx(cfg).gemm(a, b, None, shift=shift)
    yr = ref.gemm_ref(a, b, None, acc_dtype=jnp.int32, out_dtype=jnp.int8,
                      shift=shift)
    assert bool(jnp.all(y == yr))


def test_os_ws_agree(rng):
    """Both dataflows compute the same function (different schedules)."""
    cfg = GemminiConfig(dataflow=Dataflow.BOTH)
    a = _ints(rng, (256, 192))
    b = _ints(rng, (192, 320))
    d = _ints(rng, (1, 320), -500, 500, jnp.int32)
    y_os = _ctx(cfg).gemm(a, b, d, dataflow=Dataflow.OS, shift=7,
                          activation=Activation.RELU6)
    y_ws = _ctx(cfg).gemm(a, b, d, dataflow=Dataflow.WS, shift=7,
                          activation=Activation.RELU6)
    assert bool(jnp.all(y_os == y_ws))


def test_pipeline_depth_1_same_numerics(rng):
    """Design point 6 ("fully combinational"): schedule changes, math not."""
    a = _ints(rng, (256, 128))
    b = _ints(rng, (128, 128))
    y2 = _ctx(GemminiConfig(pipeline_depth=2)).gemm(a, b, None, shift=4)
    y1 = _ctx(GemminiConfig(pipeline_depth=1)).gemm(a, b, None, shift=4)
    assert bool(jnp.all(y1 == y2))


def test_xla_backend_matches_interpret(rng):
    """The dry-run path and the kernel path share numerics."""
    cfg = GemminiConfig()
    a = _ints(rng, (130, 70))
    b = _ints(rng, (70, 36))
    yi = _ctx(cfg).gemm(a, b, None, shift=6, activation=Activation.RELU)
    yx = _ctx(cfg, "xla").gemm(a, b, None, shift=6, activation=Activation.RELU)
    assert bool(jnp.all(yi == yx))


def test_engine_header_is_consistent():
    eng = elaborate(GemminiConfig(), "interpret")
    h = eng.header(1000, 512, 2048)
    assert h["TILE_M"] % h["DIM"] == 0
    assert h["GRID"][0] * h["TILE_M"] >= 1000
    assert 0 < h["UTILIZATION"] <= 1.0


def test_matmul_batched_lhs(rng):
    cfg = GemminiConfig(input_dtype="fp32", acc_dtype="fp32",
                        output_dtype="fp32")
    eng = elaborate(cfg, "interpret")
    a = _floats(rng, (2, 3, 40))
    b = _floats(rng, (40, 24))
    y = eng.matmul(a, b)
    np.testing.assert_allclose(np.asarray(y), np.asarray(a) @ np.asarray(b),
                               rtol=1e-5, atol=1e-5)
