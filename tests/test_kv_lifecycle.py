"""Page-granular KV lifecycle, end to end (ISSUE 9 acceptance locks).

* **Host offload**: a forced-eviction run with ``kv_offload=True``
  restores the victim from the host pool without re-running its committed
  prefill chunks -- strictly fewer prefill tokens than the recompute
  baseline, bit-identical output streams.
* **Prefix cache**: a shared-system-prompt trace with
  ``prefix_cache=True`` maps the shared pages copy-on-write -- strictly
  fewer prefill tokens computed (``prefix_hit_tokens > 0``), bit-identical
  output streams.
* **Off by default**: with both features off (and even on, when there is
  nothing to exploit) the engine behaves exactly like the classic paths.

Numerical invisibility is the whole contract: restore is a DMA of pages
the engine already computed, and a prefix hit maps pages holding exactly
the keys/values the skipped chunks would have written (PR-4's
chunked-vs-single-pass exactness is what makes the resumed chunk legal at
an arbitrary anchor).
"""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.models import transformer as tf
from repro.serving import ServingEngine

_TINY = tf.ModelConfig(name="tiny-serve", family="dense", n_layers=2,
                       d_model=32, vocab=64, n_heads=2, n_kv_heads=1,
                       head_dim=16, d_ff=64, dtype=jnp.float32)


def _run(prompts, gen, *, n_pages, max_context=64, **kw):
    eng = ServingEngine(_TINY, max_slots=2, max_context=max_context,
                        page_size=8, n_pages=n_pages, backend="xla",
                        seed=0, temperature=0.0, prefill_chunk=8, **kw)
    for p in prompts:
        eng.submit(np.asarray(p, np.int32), gen)
    rep = eng.run()
    toks = [np.asarray(r["tokens"]).ravel() for r in rep["requests"]]
    return eng, toks, rep["summary"]


def _evict_prompts():
    rng = np.random.default_rng(0)
    return [rng.integers(0, 64, (19,)).astype(np.int32) for _ in range(2)]


def _shared_prefix_prompts(n=4, shared=24, tail=7):
    rng = np.random.default_rng(1)
    sys_prompt = rng.integers(0, 64, (shared,)).astype(np.int32)
    return [np.concatenate([sys_prompt,
                            rng.integers(0, 64, (tail,)).astype(np.int32)])
            for _ in range(n)]


# ---------------------------------------------------------------------------
# host offload
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_offload_restores_without_recomputing_committed_chunks():
    """2 slots x 4 pages force an eviction; offload must (a) restore the
    victim rather than recompute it, (b) compute strictly fewer prefill
    tokens, (c) change no output bit."""
    prompts = _evict_prompts()
    _, base_toks, base = _run(prompts, 8, n_pages=4, max_context=32)
    assert base["preemptions"] >= 1            # geometry really evicts
    assert base["restarts_recomputed"] >= 1
    eng, off_toks, off = _run(prompts, 8, n_pages=4, max_context=32,
                              kv_offload=True)
    assert off["offload_spills"] >= 1 and off["offload_restores"] >= 1
    assert off["restarts_restored"] >= 1 and off["restarts_recomputed"] == 0
    # committed chunks were NOT re-run: fewer positions computed
    assert off["prefill_tokens"] < base["prefill_tokens"]
    for a, b in zip(base_toks, off_toks):
        np.testing.assert_array_equal(a, b)
    assert eng.alloc.host_used_pages == 0      # restored spills consumed


@pytest.mark.slow
def test_offload_pool_too_small_degrades_to_recompute():
    """A pool that cannot hold the victim refuses the spill; the run
    degrades to the classic recompute path with identical tokens.
    (``host_pool_pages=0`` is the degenerate bound -- every spill is
    larger than the pool; the LRU eviction of a merely-undersized pool is
    property-tested in test_paged_cache_props.)"""
    prompts = _evict_prompts()
    _, base_toks, base = _run(prompts, 8, n_pages=4, max_context=32)
    _, toks, s = _run(prompts, 8, n_pages=4, max_context=32,
                      kv_offload=True, host_pool_pages=0)
    assert s["offload_spills"] == 0 and s["offload_restores"] == 0
    assert s["restarts_recomputed"] >= 1
    assert s["prefill_tokens"] == base["prefill_tokens"]
    for a, b in zip(base_toks, toks):
        np.testing.assert_array_equal(a, b)


# ---------------------------------------------------------------------------
# prefix cache
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_prefix_cache_skips_shared_prefix_chunks():
    """Four requests share a 24-token system prompt over 2 slots (so
    admissions stagger and later requests see the published pages):
    strictly fewer prefill tokens, hits in telemetry, identical tokens."""
    prompts = _shared_prefix_prompts()
    _, base_toks, base = _run(prompts, 6, n_pages=16)
    eng, pc_toks, pc = _run(prompts, 6, n_pages=16, prefix_cache=True)
    assert pc["prefix_hit_tokens"] > 0
    assert pc["prefill_tokens"] < base["prefill_tokens"]
    assert pc["prefill_tokens"] + pc["prefix_hit_tokens"] == \
        base["prefill_tokens"]                 # hits account exactly
    for a, b in zip(base_toks, pc_toks):
        np.testing.assert_array_equal(a, b)
    # the index never wedges the arena: everything freed or reclaimable
    eng.alloc.check()
    assert eng.alloc.free_pages + eng.alloc.prefix_index_pages == \
        eng.alloc.n_pages


@pytest.mark.slow
def test_prefix_cache_disjoint_prompts_no_hits_bit_exact():
    """Unrelated prompts: the cache publishes but never hits, and output
    is bit-identical to the feature-off run (hash misses are free)."""
    rng = np.random.default_rng(7)
    prompts = [rng.integers(0, 64, (17 + 4 * i,)).astype(np.int32)
               for i in range(3)]
    _, base_toks, base = _run(prompts, 5, n_pages=16)
    _, pc_toks, pc = _run(prompts, 5, n_pages=16, prefix_cache=True)
    assert pc["prefix_hit_tokens"] == 0
    assert pc["prefill_tokens"] == base["prefill_tokens"]
    for a, b in zip(base_toks, pc_toks):
        np.testing.assert_array_equal(a, b)


def test_prefix_cache_rejects_recurrent_families():
    """CoW pages cannot carry recurrent scan state: an SSM family must be
    refused at construction, not silently mis-served."""
    from repro import configs
    ssm_cfg = configs.get_smoke("mamba2-1.3b")
    with pytest.raises(ValueError, match="attention-only"):
        ServingEngine(ssm_cfg, max_slots=2, max_context=32, page_size=8,
                      n_pages=8, backend="xla", prefix_cache=True)


# ---------------------------------------------------------------------------
# both features: compose + off-by-default parity
# ---------------------------------------------------------------------------
@pytest.mark.slow
def test_offload_and_prefix_cache_compose_bit_exact():
    """Both features on, under eviction pressure AND shared prefixes:
    restore takes precedence for spilled victims, prefix hits serve fresh
    admissions, and the stream never drifts."""
    prompts = _shared_prefix_prompts(n=3, shared=16, tail=5)
    _, base_toks, _ = _run(prompts, 6, n_pages=6, max_context=48)
    _, both_toks, s = _run(prompts, 6, n_pages=6, max_context=48,
                           kv_offload=True, prefix_cache=True)
    assert s["prefix_hit_tokens"] > 0
    for a, b in zip(base_toks, both_toks):
        np.testing.assert_array_equal(a, b)


def test_features_off_by_default():
    """Default-constructed engines have no host pool, no prefix index, no
    lifecycle counters -- the PR-8 surface exactly."""
    _, toks, s = _run([np.arange(9)], 3, n_pages=8, max_context=32)
    assert s["prefix_hit_tokens"] == 0 and s["offload_spills"] == 0
    assert s["offload_restores"] == 0 and s["restarts_restored"] == 0
    eng = ServingEngine(_TINY, max_slots=2, max_context=32, page_size=8,
                        n_pages=8, backend="xla")
    assert eng.alloc.host_pool_pages == 0
    assert not eng.alloc.host_put(0, 1, 8, {})   # pool refuses everything
