"""Fault tolerance + gradient compression unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.runtime import (ErrorFeedbackState, HeartbeatMonitor,
                           RestartPolicy, StragglerDetector,
                           compress_grads_with_feedback, int8_compress,
                           int8_decompress, run_with_restarts,
                           topk_compress, topk_decompress)
from repro.runtime.compression import init_error_feedback, \
    int8_roundtrip_tree


# ---------------------------------------------------------------------------
# heartbeats / stragglers / restart loop
# ---------------------------------------------------------------------------
def test_heartbeat_monitor_fake_clock():
    t = [0.0]
    mon = HeartbeatMonitor(["h0", "h1", "h2"], timeout_s=10.0,
                           clock=lambda: t[0])
    t[0] = 5.0
    mon.beat("h0")
    mon.beat("h1")
    t[0] = 12.0
    assert mon.dead() == ["h2"]
    assert set(mon.alive()) == {"h0", "h1"}


def test_straggler_detector():
    det = StragglerDetector(warmup=5, z_threshold=3.0)
    flagged = [det.observe(1.0 + 0.01 * (i % 3)) for i in range(20)]
    assert not any(flagged)
    assert det.observe(5.0)           # 5x step time -> straggler
    assert not det.observe(1.0)       # recovery is not flagged


def test_run_with_restarts_shrinks_pods():
    attempts = []

    def make_runner(attempt, pods):
        attempts.append((attempt, pods))

        def run():
            if attempt < 2:
                raise RuntimeError(f"fail {attempt}")
            return "done"
        return run

    result, n, pods = run_with_restarts(
        make_runner, RestartPolicy(max_failures=3), n_pods=2)
    assert result == "done" and n == 3
    assert attempts == [(0, 2), (1, 1), (2, 1)]   # elastic shrink 2 -> 1


def test_run_with_restarts_exhausts():
    def make_runner(attempt, pods):
        def run():
            raise RuntimeError("always")
        return run

    with pytest.raises(RuntimeError):
        run_with_restarts(make_runner, RestartPolicy(max_failures=1),
                          n_pods=1)


def test_heartbeat_unknown_host_raises():
    """A beat from an undeclared host is a liveness hole, not a no-op: a
    typo'd id would keep the phantom alive while the real host quietly
    times out."""
    mon = HeartbeatMonitor(["h0"], timeout_s=10.0, clock=lambda: 0.0)
    with pytest.raises(KeyError):
        mon.beat("h0-typo")
    mon.register("h1")
    mon.beat("h1")                        # declared: fine


def test_heartbeat_unknown_host_lenient_drops_beat():
    t = [0.0]
    mon = HeartbeatMonitor(["h0"], timeout_s=10.0, clock=lambda: t[0],
                           strict=False)
    t[0] = 20.0
    mon.beat("ghost")
    mon.beat("ghost")
    assert mon.unknown_beats == {"ghost": 2}
    # the dropped beats never counted as liveness for anyone
    assert mon.dead() == ["h0"] and "ghost" not in mon.last


def test_step_watchdog_composes_detector_and_monitor():
    from repro.runtime import StepWatchdog
    t = [0.0]
    mon = HeartbeatMonitor([], timeout_s=10.0, clock=lambda: t[0])
    det = StragglerDetector(warmup=5, z_threshold=3.0)
    dog = StepWatchdog(detector=det, monitor=mon, host="serve")
    assert "serve" in mon.last            # auto-registered
    for i in range(10):
        t[0] += 1.0
        assert not dog.observe(1.0 + 0.01 * (i % 3))
    assert dog.observe(6.0)               # 6x step time -> straggler
    stats = dog.stats()
    assert stats["straggler_steps"] == 1
    assert stats["step_p50_s"] == pytest.approx(1.01, abs=0.02)
    assert stats["step_p95_s"] > stats["step_p50_s"]
    assert mon.last["serve"] == t[0]      # every observe beat the monitor


def test_run_with_restarts_no_shrink():
    attempts = []

    def make_runner(attempt, pods):
        attempts.append((attempt, pods))

        def run():
            if attempt < 1:
                raise RuntimeError("fail")
            return "ok"
        return run

    result, n, pods = run_with_restarts(
        make_runner, RestartPolicy(max_failures=2, allow_shrink=False),
        n_pods=4)
    assert result == "ok" and pods == 4
    assert attempts == [(0, 4), (1, 4)]   # mesh size pinned


def test_run_with_restarts_on_failure_and_pod_floor():
    seen = []

    def make_runner(attempt, pods):
        def run():
            if attempt < 3:
                raise RuntimeError(f"boom {attempt} pods={pods}")
            return pods
        return run

    pods_used, n, pods = run_with_restarts(
        make_runner, RestartPolicy(max_failures=3), n_pods=2,
        on_failure=lambda a, e: seen.append((a, str(e))))
    assert n == 4 and pods == 1 == pods_used   # shrank 2 -> 1, floor at 1
    assert [a for a, _ in seen] == [0, 1, 2]
    assert "boom 0 pods=2" in seen[0][1]
    assert "boom 2 pods=1" in seen[2][1]


# ---------------------------------------------------------------------------
# compression
# ---------------------------------------------------------------------------
def test_topk_roundtrip(rng):
    g = jnp.asarray(rng.standard_normal((64,)), jnp.float32)
    vals, idx = topk_compress(g, 8)
    out = topk_decompress(vals, idx, g.shape, g.dtype)
    # kept entries exact, others zero; kept are the largest-|.|
    kept = np.zeros(64, bool)
    kept[np.asarray(idx)] = True
    assert np.all(np.asarray(out)[kept] == np.asarray(g)[kept])
    assert np.all(np.asarray(out)[~kept] == 0)
    assert np.min(np.abs(np.asarray(g)[kept])) >= \
        np.max(np.abs(np.asarray(g)[~kept])) - 1e-6


def test_error_feedback_conserves_mass(rng):
    grads = {"a": jnp.asarray(rng.standard_normal((100,)), jnp.float32)}
    state = init_error_feedback(grads)
    kept, state = compress_grads_with_feedback(grads, state, density=0.05)
    # kept + residual == original (nothing lost)
    total = kept["a"] + state.residual["a"]
    np.testing.assert_allclose(np.asarray(total), np.asarray(grads["a"]),
                               rtol=1e-6)
    # second round: residual is added back before selection
    kept2, state2 = compress_grads_with_feedback(grads, state, density=0.05)
    assert float(jnp.sum(jnp.abs(kept2["a"]))) > 0


def test_error_feedback_converges_to_dense(rng):
    """Accumulated sparse updates approach the dense gradient sum (DGC's
    convergence argument); without error feedback they cannot."""
    g = jnp.asarray(rng.standard_normal((50,)), jnp.float32)
    grads = {"g": g}
    state = init_error_feedback(grads)
    acc = jnp.zeros_like(g)
    for _ in range(60):
        kept, state = compress_grads_with_feedback(grads, state,
                                                   density=0.1)
        acc = acc + kept["g"]
    dense_sum = 60 * g
    rel = float(jnp.linalg.norm(acc - dense_sum) /
                jnp.linalg.norm(dense_sum))
    # plain top-k (no feedback) would transmit the same 5 coords forever:
    vals, idx = topk_compress(g, 5)
    plain = 60 * topk_decompress(vals, idx, g.shape, g.dtype)
    rel_plain = float(jnp.linalg.norm(plain - dense_sum) /
                      jnp.linalg.norm(dense_sum))
    assert rel < 0.2, rel
    assert rel < 0.25 * rel_plain, (rel, rel_plain)


def test_int8_compression_error_bound(rng):
    g = jnp.asarray(rng.standard_normal((1000,)) * 3, jnp.float32)
    q, scale = int8_compress(g)
    out = int8_decompress(q, scale)
    assert q.dtype == jnp.int8
    assert float(jnp.max(jnp.abs(out - g))) <= float(scale) * 0.51
    # payload shrank 4x
    assert q.nbytes * 4 == g.nbytes


def test_int8_roundtrip_tree_preserves_dtype(rng):
    grads = {"w": jnp.asarray(rng.standard_normal((8, 8)), jnp.bfloat16)}
    out = int8_roundtrip_tree(grads)
    assert out["w"].dtype == jnp.bfloat16
