"""Flash-attention Pallas kernel + XLA blockwise path vs the MHA oracle."""

import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels import attention as ak
from repro.kernels import ref
from repro.models import attention as mattn


def _t(rng, shape, dtype=jnp.float32):
    return jnp.asarray(rng.standard_normal(shape), dtype)


CASES = [
    # (b, tq, tk, h, kvh, d, causal, window, softcap)
    (2, 128, 128, 4, 2, 64, True, None, None),
    (1, 100, 100, 4, 1, 32, True, 37, None),       # MQA + window
    (1, 64, 192, 8, 4, 64, True, None, 50.0),      # Tq != Tk + softcap
    (2, 96, 96, 2, 2, 128, False, None, None),     # bidirectional
    (1, 130, 130, 4, 4, 64, True, 64, 30.0),       # window + softcap
]


@pytest.mark.parametrize("case", CASES)
def test_flash_kernel_vs_oracle(rng, case):
    b, tq, tk, h, kvh, d, causal, window, softcap = case
    q, k, v = (_t(rng, (b, tq, h, d)), _t(rng, (b, tk, kvh, d)),
               _t(rng, (b, tk, kvh, d)))
    y = ak.flash_attention(q, k, v, causal=causal, window=window,
                           softcap=softcap, block_q=64, block_k=64,
                           interpret=True)
    yr = ref.mha_ref(q, k, v, causal=causal, window=window, softcap=softcap)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("block", [32, 128])
def test_flash_kernel_block_size_invariant(rng, block):
    q = _t(rng, (1, 96, 4, 64))
    k = _t(rng, (1, 96, 2, 64))
    v = _t(rng, (1, 96, 2, 64))
    y = ak.flash_attention(q, k, v, block_q=block, block_k=block,
                           interpret=True)
    yr = ref.mha_ref(q, k, v)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


@pytest.mark.parametrize("h,kvh,s,win", [(8, 2, 300, None), (4, 1, 257, 64),
                                         (16, 16, 128, None)])
def test_decode_kernel_vs_oracle(rng, h, kvh, s, win):
    q = _t(rng, (2, 1, h, 64))
    k = _t(rng, (2, s, kvh, 64))
    v = _t(rng, (2, s, kvh, 64))
    pos = jnp.int32(s - 5)
    y = ak.decode_attention(q, k, v, pos, window=win, block_k=128,
                            interpret=True)
    yr = ref.mha_ref(q, k[:, :int(pos) + 1], v[:, :int(pos) + 1],
                     causal=True, window=win)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_blockwise_xla_vs_oracle(rng):
    """The dry-run (XLA) attention path matches the oracle too."""
    q = _t(rng, (2, 120, 4, 32))
    k = _t(rng, (2, 120, 2, 32))
    v = _t(rng, (2, 120, 2, 32))
    for win, cap in [(None, None), (48, None), (None, 25.0)]:
        y = mattn.blockwise_attention_xla(q, k, v, causal=True, window=win,
                                          softcap=cap, block_k=32)
        yr = ref.mha_ref(q, k, v, causal=True, window=win, softcap=cap)
        np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                                   rtol=2e-5, atol=2e-5)


def test_models_decode_attention_vs_oracle(rng):
    q = _t(rng, (2, 1, 8, 32))
    k = _t(rng, (2, 64, 2, 32))
    v = _t(rng, (2, 64, 2, 32))
    pos = jnp.int32(40)
    y = mattn.decode_attention(q, mattn.KVCache(k, v), pos, window=16)
    yr = ref.mha_ref(q, k[:, :41], v[:, :41], causal=True, window=16)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_kernel_window_blocks_are_skipped(rng):
    """Sliding window + causal on a long stripe: identical numerics while
    most blocks are skippable (correctness of the skip predicate)."""
    q = _t(rng, (1, 256, 2, 32))
    k = _t(rng, (1, 256, 2, 32))
    v = _t(rng, (1, 256, 2, 32))
    y = ak.flash_attention(q, k, v, causal=True, window=32,
                           block_q=32, block_k=32, interpret=True)
    yr = ref.mha_ref(q, k, v, causal=True, window=32)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_block_live_padding_term():
    """Regression: the whole-block skip test must include k0 < tk, so for
    non-causal/no-window layers a fully-padded KV block (the pad_k region)
    is skipped instead of running the MXU against the -inf mask."""
    # tk=100 with block_k=64 -> second block [64, 128) is partly live,
    # a third block [128, 192) would be fully padding.
    common = dict(block_q=64, block_k=64, tk=100, causal=False, window=None)
    assert bool(ak.block_live(0, 0, **common))
    assert bool(ak.block_live(64, 0, **common))
    assert not bool(ak.block_live(128, 0, **common))     # fully padded
    # causal + padding: both terms must hold
    assert not bool(ak.block_live(128, 0, block_q=64, block_k=64, tk=100,
                                  causal=True, window=None))
    assert not bool(ak.block_live(64, 0, block_q=32, block_k=64, tk=100,
                                  causal=True, window=None))  # causal-dead
    # window-dead block with k inside the padded range
    assert not bool(ak.block_live(0, 200, block_q=32, block_k=64, tk=256,
                                  causal=True, window=32))


@pytest.mark.parametrize("causal", [False, True])
def test_flash_kernel_padded_tk_vs_oracle(rng, causal):
    """Ragged tk with small blocks: the padded KV tail is block-skipped
    (non-causal exercises the new k0 < tk term) and numerics still match."""
    q = _t(rng, (1, 100, 4, 32))
    k = _t(rng, (1, 100, 2, 32))
    v = _t(rng, (1, 100, 2, 32))
    y = ak.flash_attention(q, k, v, causal=causal, block_q=32, block_k=32,
                           interpret=True)
    yr = ref.mha_ref(q, k, v, causal=causal)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_decode_kernel_padded_cache_vs_oracle(rng):
    """Decode against a cache whose padded tail spans whole blocks."""
    q = _t(rng, (1, 1, 4, 32))
    k = _t(rng, (1, 130, 2, 32))
    v = _t(rng, (1, 130, 2, 32))
    y = ak.decode_attention(q, k, v, jnp.int32(100), block_k=64,
                            interpret=True)
    yr = ref.mha_ref(q, k[:, :101], v[:, :101], causal=True)
    np.testing.assert_allclose(np.asarray(y), np.asarray(yr),
                               rtol=2e-5, atol=2e-5)


def test_bf16_inputs(rng):
    q = _t(rng, (1, 64, 4, 64), jnp.bfloat16)
    k = _t(rng, (1, 64, 2, 64), jnp.bfloat16)
    v = _t(rng, (1, 64, 2, 64), jnp.bfloat16)
    y = ak.flash_attention(q, k, v, block_q=32, block_k=32, interpret=True)
    yr = ref.mha_ref(q, k, v)
    assert y.dtype == jnp.bfloat16
    np.testing.assert_allclose(np.asarray(y, np.float32),
                               np.asarray(yr, np.float32),
                               rtol=3e-2, atol=3e-2)
