import os
import subprocess
import sys

import numpy as np
import pytest

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))
SRC = os.path.join(REPO, "src")


@pytest.fixture
def rng():
    return np.random.default_rng(0)


def _run_subprocess(code: str, n_devices: int = 8, timeout: int = 480):
    """Run ``code`` in a fresh python with a forced multi-device CPU.

    Multi-device tests must not set xla_force_host_platform_device_count in
    this process (smoke tests see 1 device), so they run isolated.
    """
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + os.pathsep + env.get("PYTHONPATH", "")
    env["XLA_FLAGS"] = (f"--xla_force_host_platform_device_count={n_devices} "
                        + env.get("XLA_FLAGS", ""))
    p = subprocess.run([sys.executable, "-c", code], env=env,
                       capture_output=True, text=True, timeout=timeout)
    assert p.returncode == 0, f"STDOUT:\n{p.stdout}\nSTDERR:\n{p.stderr}"
    return p.stdout


@pytest.fixture
def run_subprocess():
    return _run_subprocess
